"""Store-wide scrub & repair (fsck): corruption detection across every
tier and object kind, bit-exact self-healing, replication-debt backfill,
canonical-cache re-derivation, quarantine lifecycle, and the guarantee
that corrupt bytes are never silently served."""
import dataclasses

import jax
import numpy as np
import pytest

from proptest import cases

from repro.checkpoint import ChunkStore, StoreScrubber, scrub_root
from repro.checkpoint.saver import CheckpointManager
from repro.configs import get_config
from repro.core import LayerRegistry, make_policy
from repro.core.manifest import Manifest, ManifestStore
from repro.launch import steps as steps_lib
from repro.models import build_model

BB = 4096
REMOTE_OPTS = {"latency": 0.0, "seed": 3}


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("llama3.2-3b", reduced=True)
    model = build_model(cfg)
    state = steps_lib.init_state(model, jax.random.key(0))
    return model, state, LayerRegistry(model)


def _drift_unit(registry, state, unit, n=10):
    sub = registry.extract_unit(state["params"], unit)
    leaves, treedef = jax.tree.flatten(sub)
    a = np.asarray(leaves[0]).copy()
    a.flat[:n] += 1
    leaves[0] = jax.numpy.asarray(a)
    return dict(state, params=registry.insert_unit(
        state["params"], unit, jax.tree.unflatten(treedef, leaves)))


def _mgr(root, registry, pol, **kw):
    kw.setdefault("remote_opts", dict(REMOTE_OPTS))
    return CheckpointManager(root, registry, pol, async_save=False,
                             store_backend="remote3", fp_block_bytes=BB,
                             spill_barrier=True, **kw)


def _synthetic_store(root):
    """A model-free corpus holding every classic object kind: a full
    object, an XOR-delta on it, and a sharded entry (two spec-carrying
    refs) — committed under one manifest so the scrubber walks them."""
    store = ChunkStore(root, backend="remote3",
                      remote_opts=dict(REMOTE_OPTS))
    rs = np.random.RandomState(7)
    base = {"w": rs.standard_normal(4096).astype(np.float32)}
    r_full = store.write(10, "x0", "weights", base)
    cur = {"w": base["w"].copy()}
    cur["w"][5] += 1.0
    r_delta = store.write(20, "x0", "weights", cur,
                          delta_base=r_full.digest)
    assert r_delta.stored == "delta"
    shard_refs = tuple(
        dataclasses.replace(
            store.write(20, "xs", "weights",
                        {"w": rs.standard_normal(256).astype(np.float32)}),
            spec={"participant": i})
        for i in range(2))
    m = Manifest(step=20, entries={
        "x0": {"weights": r_delta},
        "xs": {"weights": shard_refs},
    })
    ManifestStore(root).commit(m)
    store.drain_spill()
    return store, {"full": r_full.digest, "delta": r_delta.digest,
                   "shard": shard_refs[0].digest}


def test_scrub_healthy_store_reports_clean(tmp_path):
    store, kinds = _synthetic_store(tmp_path)
    report = StoreScrubber(store).scrub()
    assert report["v"] == 1 and report["repair"]
    assert report["checked_objects"] == 4  # full, delta, 2 shard objects
    assert report["healthy"] == report["checked_objects"]
    assert not report["repaired"] and not report["unrecoverable"]
    assert not report["demoted_manifests"]
    # every object was verified on both durable tiers
    assert report["checked_tiers"]["durable"] == report["checked_objects"]
    assert report["checked_tiers"]["remote"] == report["checked_objects"]
    store.close()


# ------------------------------------------------ the core property test
def test_scrub_flip_any_byte_any_kind_any_tier_property(tmp_path):
    """A single byte flip in ANY stored object kind (full, XOR-delta,
    shard object) in ANY tier holding a copy is detected by the scrub
    and repaired BIT-EXACT from a tier holding a good copy."""
    store, _ = _synthetic_store(tmp_path)
    tiers = store.backend.tier_backends()
    pristine = {}  # (label, digest) -> good blob
    for label, tier in tiers.items():
        for d in tier.keys():
            pristine[(label, d)] = tier.read(d)
    sites = sorted(pristine)

    def gen(rs):
        label, d = sites[rs.randint(len(sites))]
        off = int(rs.randint(len(pristine[(label, d)])))
        return label, d, off

    for label, digest, off in cases(10, gen):
        blob = bytearray(pristine[(label, digest)])
        blob[off] ^= 0xFF
        tiers[label].write(digest, bytes(blob))
        report = StoreScrubber(store).scrub()
        by_digest = {r["digest"]: r for r in report["repaired"]}
        assert digest in by_digest, (label, digest, off)
        rec = by_digest[digest]
        assert rec["method"] == "replicate" and rec["repaired"]
        assert rec["bad_tiers"] == [label]
        assert rec["repaired_from"] != label
        assert not report["unrecoverable"], (label, digest, off)
        # the repair is bit-exact, not merely "something was written"
        assert tiers[label].read(digest) == pristine[(label, digest)], \
            (label, digest, off)
    store.close()


def test_scrub_backfills_missing_deepest_tier_copy(tmp_path):
    """Absence from a fast tier is eviction; absence from the DEEPEST
    tier is replication debt (a degraded commit whose process died) —
    the scrub backfills it from any good copy."""
    store, kinds = _synthetic_store(tmp_path)
    tiers = store.backend.tier_backends()
    victim = kinds["full"]
    assert tiers["remote"].delete(victim) > 0
    report = StoreScrubber(store).scrub()
    rec = {r["digest"]: r for r in report["repaired"]}[victim]
    assert rec["method"] == "backfill"
    assert rec["bad_tiers"] == ["remote"]
    assert tiers["remote"].has(victim)
    assert not report["unrecoverable"]
    # a hot-tier (non-deepest) miss is NOT debt: nothing to repair
    assert tiers["hot"].delete(kinds["delta"]) > 0
    report2 = StoreScrubber(store).scrub()
    assert not report2["repaired"] and not report2["unrecoverable"]
    store.close()


def test_scrub_rederives_from_canonical_cache(tmp_path):
    """Corrupt in EVERY tier but still in the writing process's
    canonical cache: the scrub rebuilds a fresh full envelope under the
    same digest (canonical-addressed digests hash the payload)."""
    store, kinds = _synthetic_store(tmp_path)
    tiers = store.backend.tier_backends()
    victim = kinds["full"]
    for label, tier in tiers.items():
        if tier.has(victim):
            blob = bytearray(tier.read(victim))
            blob[len(blob) // 2] ^= 0xFF
            tier.write(victim, bytes(blob))
    report = StoreScrubber(store).scrub()
    rec = {r["digest"]: r for r in report["repaired"]}[victim]
    assert rec["method"] == "rederive"
    assert rec["repaired_from"] == "canonical-cache"
    assert not report["unrecoverable"]
    out = store.read_canonical(victim)  # verify=True: digest re-checked
    assert out is not None
    store.close()


def test_scrub_repairs_corrupt_block_delta_object(tmp_path, small_setup):
    """The fp pipeline's BD02 block-sparse delta objects heal like any
    other kind: flip a byte in the disk copy, repair from remote."""
    model, state, registry = small_setup
    pol = make_policy("full", model.layer_units())
    mgr = _mgr(tmp_path, registry, pol)
    mgr.save(state, step=10)
    state2 = _drift_unit(registry, state, "block_000")
    mgr.save(state2, step=20)
    victim = mgr.manifests.load(20).entries["block_000"]["weights"].digest
    tiers = mgr.store.backend.tier_backends()
    good = tiers["durable"].read(victim)
    blob = bytearray(good)
    blob[len(blob) // 2] ^= 0xFF
    tiers["durable"].write(victim, bytes(blob))
    report = mgr.scrub()
    rec = {r["digest"]: r for r in report["repaired"]}[victim]
    assert rec["method"] == "replicate" and "durable" in rec["bad_tiers"]
    assert tiers["durable"].read(victim) == good
    restored = mgr.restore(steps_lib.state_specs(model))
    stats = mgr.last_restore_stats
    assert not stats["fallback_units"] and not stats["quarantined_skipped"]
    exp = registry.extract_unit(state2["params"], "block_000")
    got = registry.extract_unit(restored["params"], "block_000")
    for a, b in zip(jax.tree.leaves(exp), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


# --------------------------------------- unrecoverable: quarantine, honesty
def test_unrecoverable_quarantines_demotes_and_never_serves(
        tmp_path, small_setup):
    model, state, registry = small_setup
    pol = make_policy("full", model.layer_units())
    mgr = _mgr(tmp_path, registry, pol)
    mgr.save(state, step=10)
    state2 = _drift_unit(registry, state, "block_000")
    mgr.save(state2, step=20)
    m2 = mgr.manifests.load(20)
    victim = m2.entries["block_000"]["weights"].digest
    mgr.close()

    # restart: canonical cache cold, hot tier empty -> no re-derivation
    mgr2 = _mgr(tmp_path, registry, pol)
    tiers = mgr2.store.backend.tier_backends()
    good = {}
    for label in ("durable", "remote"):
        good[label] = tiers[label].read(victim)
        blob = bytearray(good[label])
        blob[len(blob) // 2] ^= 0xFF
        tiers[label].write(victim, bytes(blob))

    report = mgr2.scrub()
    rec = {r["digest"]: r for r in report["unrecoverable"]}[victim]
    assert rec["reason"] == "corrupt in every tier"
    assert 20 in rec["manifests"]
    assert ["block_000", "weights"] in rec["units"]
    assert 20 in report["demoted_manifests"]
    assert mgr2.store.quarantined(victim)
    assert mgr2.store.quarantine_path.is_file()

    # the restore NEVER silently serves the corrupt object: the planner
    # skips the quarantined digest up front and block_000 falls back to
    # its step-10 content; every other unit restores at step 20.
    restored = mgr2.restore(steps_lib.state_specs(model))
    stats = mgr2.last_restore_stats
    assert stats["quarantined_skipped"] >= 1
    exp10 = registry.extract_unit(state["params"], "block_000")
    got = registry.extract_unit(restored["params"], "block_000")
    for a, b in zip(jax.tree.leaves(exp10), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    other = next(u.name for u in model.layer_units()
                 if u.name != "block_000")
    exp20 = registry.extract_unit(state2["params"], other)
    got20 = registry.extract_unit(restored["params"], other)
    for a, b in zip(jax.tree.leaves(exp20), jax.tree.leaves(got20)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # operator restores the bytes -> the next scrub releases quarantine
    tiers["durable"].write(victim, good["durable"])
    report2 = mgr2.scrub()
    assert victim in report2["released_from_quarantine"]
    assert not mgr2.store.quarantined(victim)
    assert report2["quarantined"] == 0
    assert not report2["unrecoverable"]
    # remote's corrupt copy was repaired from the restored durable one
    rec2 = {r["digest"]: r for r in report2["repaired"]}[victim]
    assert rec2["method"] == "replicate" and "remote" in rec2["bad_tiers"]
    mgr2.close()


def test_quarantine_survives_restart_and_blocks_planning(tmp_path):
    store, kinds = _synthetic_store(tmp_path)
    store.close()
    # restart first: the canonical cache is cold, so a corrupt-everywhere
    # shard object cannot be re-derived
    store1 = ChunkStore(tmp_path, backend="remote3",
                        remote_opts=dict(REMOTE_OPTS))
    tiers = store1.backend.tier_backends()
    victim = kinds["shard"]
    for label in ("durable", "remote"):
        blob = bytearray(tiers[label].read(victim))
        blob[0] ^= 0xFF
        tiers[label].write(victim, bytes(blob))
    report = StoreScrubber(store1).scrub()
    assert [r["digest"] for r in report["unrecoverable"]] == [victim]
    store1.close()
    # a second fresh store loads the quarantine from disk
    store2 = ChunkStore(tmp_path, backend="remote3",
                        remote_opts=dict(REMOTE_OPTS))
    assert store2.quarantined(victim)
    assert not store2.quarantined(kinds["full"])
    store2.close()


def test_audit_mode_reports_without_touching_bytes(tmp_path):
    store, kinds = _synthetic_store(tmp_path)
    tiers = store.backend.tier_backends()
    victim = kinds["delta"]
    blob = bytearray(tiers["durable"].read(victim))
    blob[3] ^= 0xFF
    corrupt = bytes(blob)
    tiers["durable"].write(victim, corrupt)
    report = StoreScrubber(store).scrub(repair=False)
    rec = {r["digest"]: r for r in report["repaired"]}[victim]
    assert rec["repaired"] is False and not report["repair"]
    assert tiers["durable"].read(victim) == corrupt, \
        "audit mode must not write"
    assert not store.quarantine_path.is_file()
    store.close()


def test_scrub_root_offline_entry(tmp_path):
    store, kinds = _synthetic_store(tmp_path)
    tiers = store.backend.tier_backends()
    blob = bytearray(tiers["durable"].read(kinds["full"]))
    blob[-1] ^= 0xFF
    tiers["durable"].write(kinds["full"], bytes(blob))
    store.close()
    report = scrub_root(tmp_path, backend="remote3",
                        remote_opts=dict(REMOTE_OPTS))
    assert {r["digest"] for r in report["repaired"]} >= {kinds["full"]}
    assert not report["unrecoverable"]
