"""Decode-vs-prefill consistency: for every decoder-bearing arch, one
decode step against a prefilled cache must match the logits of prefilling
the extended prompt (bf16 tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

T = 32
B = 2


def _grow(cache, target):
    """Pad all cache sequence dims out to ``target`` (ssm caches untouched)."""

    def pad_seq(x, axis):
        if x.shape[axis] >= target:
            return x
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, target - x.shape[axis])
        return jnp.pad(x, pads)

    def walk(node, key=""):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(pad_seq(t, t.ndim - 3) for t in node)
        if key in ("k", "v", "cross_k", "cross_v"):
            return pad_seq(node, node.ndim - 3)
        if key in ("latent", "rope"):
            return pad_seq(node, node.ndim - 2)
        return node

    return walk(cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(1)
    toks = rng.randint(0, cfg.vocab_size, (B, T + 1)).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vlm.num_patches,
                                 cfg.vlm.patch_embed_dim)) * 0.1, jnp.bfloat16)
    if cfg.family == "encdec":
        extra["frames"] = jnp.asarray(
            rng.standard_normal((B, T, cfg.d_model)) * 0.1, jnp.bfloat16)

    lp, cache = model.prefill(params, {"tokens": toks[:, :T], **extra})
    prefix = cfg.vlm.num_patches if cfg.family == "vlm" else 0
    cache = _grow(cache, prefix + T + 1)
    pos = jnp.int32(prefix + T)
    ld, _ = model.decode_step(params, cache,
                              {"tokens": toks[:, T:T + 1], "pos": pos})
    lf, _ = model.prefill(params, {"tokens": toks[:, :T + 1], **extra})
    err = float(jnp.max(jnp.abs(ld.astype(jnp.float32)
                                - lf.astype(jnp.float32))))
    assert err < 0.06, f"{arch}: decode/prefill mismatch {err}"


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-370m"])
def test_multi_step_decode_matches_prefill(arch):
    """Five decode steps chained == prefill of the 5-longer prompt."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(2)
    n_extra = 5
    toks = rng.randint(0, cfg.vocab_size, (B, T + n_extra)).astype(np.int32)
    _, cache = model.prefill(params, {"tokens": toks[:, :T]})
    cache = _grow(cache, T + n_extra)
    logits = None
    for i in range(n_extra):
        logits, cache = model.decode_step(
            params, cache, {"tokens": toks[:, T + i:T + i + 1],
                            "pos": jnp.int32(T + i)})
    lf, _ = model.prefill(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32)
                                - lf.astype(jnp.float32))))
    assert err < 0.1, f"{arch}: multi-step decode drift {err}"
