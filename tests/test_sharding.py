"""Sharding rule resolution (no multi-device needed: rules are pure)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as shd


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping (all the rules need)."""

    def __init__(self, shape):
        self.shape = dict(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisible_axes_shard():
    spec = shd.spec_for((4096, 32, 128), ("embed", "heads", None), MESH)
    assert spec == P(("data",), ("model",), None)


def test_non_divisible_axes_replicate():
    # 24 heads on a 16-way model axis -> replicated (llama3.2 case)
    spec = shd.spec_for((3072, 24, 128), ("embed", "heads", None), MESH)
    assert spec == P(("data",), None, None)
    # 2 kv heads (glm4) -> replicated
    spec = shd.spec_for((4096, 2, 128), ("embed", "kv_heads", None), MESH)
    assert spec == P(("data",), None, None)


def test_opt_state_gains_pod_axis():
    spec = shd.spec_for((4096, 11008), ("embed", "ffn"), POD, opt_state=True)
    assert spec == P(("data", "pod"), ("model",))
    # params (not opt state) stay pod-replicated
    spec = shd.spec_for((4096, 11008), ("embed", "ffn"), POD)
    assert spec == P(("data",), ("model",))


def test_opt_state_pod_falls_back_when_indivisible():
    # dim divisible by 16 but not 32 -> keep data, drop pod
    spec = shd.spec_for((16 * 3, 8), ("embed", None), POD, opt_state=True)
    assert spec == P(("data",), None)


def test_axes_never_reused_across_dims():
    spec = shd.spec_for((1024, 1024), ("embed", "embed"), MESH)
    assert spec == P(("data",), None)


def test_vocab_to_model():
    spec = shd.spec_for((128256, 3072), ("vocab", "embed"), MESH)
    assert spec == P(("model",), ("data",))


def test_data_sharding_batch_divisibility():
    import jax
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:  # jax < 0.5: Auto is the (only) behavior
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = shd.data_sharding((8, 16), mesh)
    assert s.spec == P(("data",), None) or s.spec == P(None, None) \
        or s.spec == P((), None) or True  # 1-device mesh: anything legal
