"""Per-arch smoke tests (deliverable f): every assigned architecture's
reduced config runs one forward/train step on CPU — output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import TrainConfig
from repro.launch import steps as steps_lib
from repro.models import build_model


def _batch_for(cfg, b=2, s=32, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": rng.randint(0, cfg.vocab_size, (b, s)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.vlm.num_patches,
                                 cfg.vlm.patch_embed_dim)) * 0.1, jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)) * 0.1, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    loss, metrics = model.loss(params, _batch_for(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-lite-16b",
                                  "mamba2-370m", "zamba2-2.7b",
                                  "seamless-m4t-medium"])
def test_train_step_updates_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    state = steps_lib.init_state(model, jax.random.key(0))
    step = jax.jit(steps_lib.make_train_step(model, tcfg))
    before = np.concatenate([
        np.asarray(x, dtype=np.float32).ravel()
        for x in jax.tree.leaves(state["params"])])
    state, metrics = step(state, _batch_for(cfg))   # step 0: lr=0 (warmup)
    state, metrics = step(state, _batch_for(cfg, seed=1))  # lr > 0
    after = np.concatenate([
        np.asarray(x, dtype=np.float32).ravel()
        for x in jax.tree.leaves(state["params"])])
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 2
    assert not np.array_equal(before, after), "params did not update"
    for leaf in jax.tree.leaves(state["opt"]["m"]):
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    assert cfg.num_layers > 0 and cfg.vocab_size > 0
    # abstract init must work at FULL size (no allocation)
    model = build_model(cfg)
    shapes = model.param_shapes()
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert n > 1e8, f"{arch} suspiciously small: {n}"


def test_param_counts_plausible():
    # spot-check well-known sizes (within 20%)
    expected = {"llama3.2-3b": 3.2e9, "yi-9b": 8.8e9, "glm4-9b": 9.4e9,
                "mamba2-370m": 3.7e8, "arctic-480b": 4.8e11}
    for arch, target in expected.items():
        model = build_model(get_config(arch))
        shapes = model.param_shapes()
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert 0.7 * target < n < 1.35 * target, (arch, n, target)
