"""Zero-stall overlapped checkpointing: parity, mispredictions, crashes.

The tentpole invariant (docs/perf.md): an overlapped save is a
bit-for-bit peer of a synchronous save — identical manifests (digest,
stored form, delta base per entry), identical object sets on disk,
bit-exact restores — no matter how the dirty-block predictor guesses,
and no matter where in the overlap window an injected crash lands
(previous manifest stays LATEST, zero-fallback restore).  Plus the
staging-arena hygiene invariants: backpressure bounds checked-out slots,
grow-in-place keeps segment names stable, close unlinks everything.
"""
import glob
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import faults
from repro.checkpoint.async_io import AsyncWriteError, StagingArena
from repro.checkpoint.faults import InjectedCrash
from repro.checkpoint.overlap import DirtyPredictor, OverlappedSaver
from repro.checkpoint.saver import CheckpointManager
from repro.configs import get_config
from repro.core import LayerRegistry, make_policy
from repro.launch import steps as steps_lib
from repro.models import build_model

ARCH = "llama3.2-3b"
BB = 4096


def _own_shm():
    return sorted(glob.glob(f"/dev/shm/repro-io-{os.getpid():x}-*"))


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.disarm()
    yield
    faults.disarm()


def _poke_all(state):
    def poke(x):
        x = np.array(x)
        x.flat[:1] += 1
        return x

    return {"step": np.array(state["step"]),
            "params": jax.tree.map(poke, state["params"]),
            "opt": jax.tree.map(poke, state["opt"])}


def _poke_one(state):
    """Drift exactly one element of one leaf: most units dedup clean,
    one unit goes delta with a single dirty block."""
    leaves = jax.tree.leaves(state["params"])
    target = max(leaves, key=lambda x: np.asarray(x).size)
    tid = id(target)

    def poke(x):
        if id(x) != tid:
            return np.array(x)
        x = np.array(x)
        x.flat[-1:] += 2
        return x

    return {"step": np.array(state["step"]),
            "params": jax.tree.map(poke, state["params"]),
            "opt": jax.tree.map(np.array, state["opt"])}


def _poke_blocks(state, want=4):
    """Dirty a handful of scattered 4 KiB blocks of the biggest leaf:
    sparse enough to stay on the delta path, dirty enough that a
    1-block capacity guess must overflow."""
    leaves = jax.tree.leaves(state["params"])
    target = max(leaves, key=lambda x: np.asarray(x).size)
    tid = id(target)
    epb = BB // np.asarray(target).dtype.itemsize
    nb = max(1, -(-np.asarray(target).nbytes // BB))
    k = max(2, min(want, nb // 4))

    def poke(x):
        if id(x) != tid:
            return np.array(x)
        x = np.array(x)
        for i in range(k):
            x.flat[i * epb] += 3
        return x

    return {"step": np.array(state["step"]),
            "params": jax.tree.map(poke, state["params"]),
            "opt": jax.tree.map(np.array, state["opt"])}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH, reduced=True)
    model = build_model(cfg)
    s1 = steps_lib.init_state(model, jax.random.key(0))
    s2 = _poke_all(s1)        # dense drift: every leaf dirty
    s3 = _poke_one(s2)        # sparse drift: one dirty block total
    s4 = _poke_blocks(s3)     # scattered drift: a few dirty blocks
    return model, LayerRegistry(model), [s1, s1, s2, s3, s4]


#: (step, state-index) sequence every parity test replays: full base,
#: clean re-save (dedup), dense drift, sparse drift, scattered drift.
EVENTS = [(10, 0), (20, 1), (30, 2), (40, 3), (50, 4)]


def _assert_states_equal(a, b):
    for part in ("params", "opt"):
        for x, y in zip(jax.tree.leaves(a[part]), jax.tree.leaves(b[part])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _manifest_sig(mgr, step):
    m = mgr.manifests.load(step)
    assert m is not None
    return {(unit, kind): (e.digest, e.stored, e.delta_base)
            for unit, kinds in m.entries.items()
            for kind, e in kinds.items()}


def _mgr(root, model, registry, **kw):
    kw.setdefault("fp_block_bytes", BB)
    return CheckpointManager(root, registry,
                             make_policy("full", model.layer_units()), **kw)


def _run_sync(root, model, registry, states, **kw):
    mgr = _mgr(root, model, registry, **kw)
    for step, si in EVENTS:
        mgr.save(states[si], step=step)
    sigs = {s: _manifest_sig(mgr, s) for s, _ in EVENTS}
    digests = sorted(mgr.store.iter_digests())
    mgr.close()
    return sigs, digests


def _run_overlapped(root, model, registry, states, *, predictor=None,
                    spread=2, **kw):
    mgr = _mgr(root, model, registry, **kw)
    ov = OverlappedSaver(mgr, spread_steps=spread)
    if predictor is not None:
        ov.predictor = predictor
    stats = []
    for step, si in EVENTS:
        ov.begin(states[si], step)
        ticks = 0
        while ov.tick() is None:
            ticks += 1
            assert ticks < 100
        stats.append(dict(mgr.last_save_stats))
    sigs = {s: _manifest_sig(mgr, s) for s, _ in EVENTS}
    digests = sorted(mgr.store.iter_digests())
    ov.close()
    mgr.close()
    return sigs, digests, stats


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("store", ["local", "tiered"])
def test_overlapped_matches_sync_bit_exact(setup, tmp_path, store):
    """Same event sequence through both savers: identical manifests,
    identical object sets, bit-exact restore of the overlapped chain."""
    model, registry, states = setup
    sy_sigs, sy_digests = _run_sync(tmp_path / "sync", model, registry,
                                    states, store_backend=store)
    ov_sigs, ov_digests, stats = _run_overlapped(
        tmp_path / "ov", model, registry, states, store_backend=store)
    assert sy_sigs == ov_sigs
    assert sy_digests == ov_digests
    for s in stats:
        assert s["save_mode"] == "overlapped"
        assert s["spread_steps"] == 2
    # clean re-save dedup'd without staging a byte
    assert stats[1]["d2h_bytes"] == 0
    assert stats[1]["staged_bytes"] == 0
    # sparse drift moved ~one block, not the model
    assert 0 < stats[3]["d2h_bytes"] <= 4 * BB
    assert 0 < stats[3]["dirty_block_frac"] < 0.05

    mgr = _mgr(tmp_path / "ov", model, registry, async_save=False,
               store_backend=store)
    got = mgr.restore(steps_lib.state_specs(model), step=40)
    assert not mgr.last_restore_stats["fallback_units"]
    _assert_states_equal(states[3], got)
    mgr.close()
    assert not _own_shm()


class _FixedPredictor(DirtyPredictor):
    """Misprediction on demand: always guess ``n`` blocks."""

    def __init__(self, n):
        super().__init__()
        self._n = n

    def predict(self, name, kind, path, n_blocks, drift):
        return min(max(1, self._n), n_blocks)


@pytest.mark.parametrize("guess,expect_overflow", [
    (1, True),        # under-predict everything: every delta overflows
    (1 << 20, False),  # over-predict everything: full-capacity gathers
])
def test_misprediction_never_changes_committed_bytes(setup, tmp_path,
                                                     guess, expect_overflow):
    """The property behind 'prediction is advisory': force the predictor
    maximally wrong in BOTH directions — the committed manifests and
    object digests still match the sync saver exactly; only the
    overflow-redispatch counter moves."""
    model, registry, states = setup
    sy_sigs, sy_digests = _run_sync(tmp_path / "sync", model, registry,
                                    states)
    ov_sigs, ov_digests, stats = _run_overlapped(
        tmp_path / "ov", model, registry, states,
        predictor=_FixedPredictor(guess))
    assert sy_sigs == ov_sigs
    assert sy_digests == ov_digests
    redispatches = sum(s["overflow_redispatches"] for s in stats)
    if expect_overflow:
        # the dense-drift event's deltas cannot fit in 1 block
        assert redispatches > 0
    else:
        assert redispatches == 0


def test_spread_slices_and_forced_finish(setup, tmp_path):
    """spread_steps=N really slices the staging across N ticks, and a
    new begin() mid-spread force-finishes the in-flight event first
    (strict FIFO: one manifest per event, order preserved)."""
    model, registry, states = setup
    mgr = _mgr(tmp_path, model, registry)
    ov = OverlappedSaver(mgr, spread_steps=3)
    ov.begin(states[0], 10)
    assert ov.active
    assert ov.tick() is None          # slice 1 of 3
    # new event arrives mid-spread: event 1 must commit first
    ov.begin(states[2], 20)
    assert mgr.manifests.latest_step() == 10
    assert ov.active
    m = ov.finish()
    assert m is not None and m.step == 20
    assert mgr.manifests.all_steps() == [10, 20]
    got = mgr.restore(steps_lib.state_specs(model), step=20)
    _assert_states_equal(states[2], got)
    ov.close()
    mgr.close()
    assert not _own_shm()


# ------------------------------------------------------------ crash matrix
@pytest.mark.parametrize("store", ["local", "tiered"])
@pytest.mark.parametrize("point,hit", [
    ("snapshot_overlap", 1),   # die with the whole event in flight
    ("spread_slice", 1),       # die before any slice ran
    ("spread_slice", 2),       # die mid-spread: some units already written
])
def test_crash_mid_overlap_previous_manifest_wins(setup, tmp_path, store,
                                                  point, hit):
    """Crash anywhere inside the overlap window: nothing of the doomed
    event is visible — the previous manifest stays LATEST and restores
    bit-exact with zero fallbacks, and the chain keeps working after
    the restart (GC sweeps the orphaned objects)."""
    model, registry, states = setup
    mgr = _mgr(tmp_path, model, registry, store_backend=store)
    ov = OverlappedSaver(mgr, spread_steps=2)
    ov.begin(states[0], 10)
    while ov.tick() is None:
        pass
    with faults.scoped(point, hit=hit):
        with pytest.raises((InjectedCrash, AsyncWriteError)):
            ov.begin(states[2], 20)
            while ov.tick() is None:
                pass
    assert not faults.pending()
    ov.close()
    try:
        mgr.close()
    except (AsyncWriteError, InjectedCrash):
        pass

    mgr2 = _mgr(tmp_path, model, registry, async_save=False,
                store_backend=store)
    assert mgr2.manifests.latest_step() == 10
    got = mgr2.restore(steps_lib.state_specs(model))
    assert not mgr2.last_restore_stats["fallback_units"]
    _assert_states_equal(states[0], got)
    # the chain continues: the retried event commits and restores
    ov2 = OverlappedSaver(mgr2, spread_steps=2)
    ov2.begin(states[2], 20)
    m = ov2.finish()
    assert m is not None and mgr2.manifests.latest_step() == 20
    got = mgr2.restore(steps_lib.state_specs(model), step=20)
    _assert_states_equal(states[2], got)
    ov2.close()
    mgr2.close()
    assert not _own_shm()


def test_crash_points_cataloged():
    assert "snapshot_overlap" in faults.CRASH_POINTS
    assert "spread_slice" in faults.CRASH_POINTS


# ------------------------------------------------------------ staging arena
def test_staging_arena_backpressure_and_growth():
    # max_slots caps the arena: acquire blocks (hard backpressure)
    # instead of minting a new segment.
    arena = StagingArena(slots=2, min_bytes=4096, max_slots=2)
    names0 = arena.segment_names()
    assert len(names0) == 2
    a = arena.acquire(100)
    b = arena.acquire(100)
    with pytest.raises(AsyncWriteError):
        arena.acquire(100, timeout=0.05)   # both slots checked out

    released = []

    def _later():
        time.sleep(0.05)
        released.append(True)
        arena.release(a)

    t = threading.Thread(target=_later)
    t.start()
    c = arena.acquire(100, timeout=5.0)    # blocks until the release
    t.join()
    assert released == [True]
    arena.release(b)
    arena.release(c)

    # grow-in-place: same segment name, bigger capacity, exact bytes
    payload = os.urandom(10000)
    big = arena.acquire(len(payload))
    assert big.capacity >= len(payload)
    view = big.pack(payload)
    assert bytes(view) == payload
    assert arena.segment_names() == names0
    for s in arena.segment_names():
        assert os.path.exists(f"/dev/shm/{s}")
    del view
    arena.release(big)
    arena.close()
    for s in names0:
        assert not os.path.exists(f"/dev/shm/{s}")
    with pytest.raises(AsyncWriteError):
        arena.acquire(1)


def test_staging_arena_mints_slots_unbounded():
    # Default (no max_slots): a slow writeback never stalls staging —
    # acquire mints a fresh segment instead of blocking, and released
    # segments are recycled rather than re-minted.
    arena = StagingArena(slots=1, min_bytes=4096)
    a = arena.acquire(10)
    b = arena.acquire(10, timeout=0.5)
    names = arena.segment_names()
    assert len(names) == 2
    arena.release(a)
    arena.release(b)
    c = arena.acquire(10)
    assert len(arena.segment_names()) == 2
    arena.release(c)
    arena.close()
    for s in names:
        assert not os.path.exists(f"/dev/shm/{s}")


def test_staging_slot_pack_appends():
    arena = StagingArena(slots=1, min_bytes=4096)
    slot = arena.acquire(64)
    v1 = slot.pack(b"abc")
    v2 = slot.pack(np.arange(4, dtype=np.uint8))
    assert bytes(v1) == b"abc"
    assert bytes(v2) == bytes([0, 1, 2, 3])
    del v1, v2
    arena.release(slot)
    # reacquire resets the write cursor
    slot = arena.acquire(64)
    v = slot.pack(b"xyz")
    assert bytes(v) == b"xyz"
    del v
    arena.release(slot)
    arena.close()


# --------------------------------------------------------------- predictor
def test_predictor_advisory_lifecycle():
    p = DirtyPredictor(margin=1.5)
    # first sight: predict everything (cannot overflow)
    assert p.predict("u", "weights", "w", 64, None) == 64
    p.observe("u", "weights", "w", 4)
    # afterwards: last count x margin, clamped to [1, n_blocks]
    assert p.predict("u", "weights", "w", 64, None) == 6
    assert p.predict("u", "weights", "w", 64, 1.0) == 12   # drift widens
    assert p.predict("u", "weights", "w", 64, 123.0) == 12  # drift clamped
    p.observe("u", "weights", "w", 0)
    assert p.predict("u", "weights", "w", 64, None) == 1   # floor of 1
    p.observe("u", "weights", "w", 1000)
    assert p.predict("u", "weights", "w", 64, None) == 64  # ceiling


def test_overlap_requires_fingerprint(setup, tmp_path):
    model, registry, _ = setup
    mgr = _mgr(tmp_path, model, registry, fingerprint=False)
    with pytest.raises(ValueError, match="fingerprint"):
        OverlappedSaver(mgr)
    mgr.close()
