"""Shard-native checkpointing: block math, the slice-intersection
property (planned reads exactly cover the target's addressable indices),
the two-phase commit barrier (crash-injected), resharded restores that
read strictly fewer bytes, shard-set merges, and the mesh subprocess
path (save on 1x8 -> restore on 2x4)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from proptest import cases, rand_shape
from repro.checkpoint.saver import CheckpointManager
from repro.checkpoint.sharded import (
    ShardBarrierError,
    ShardCoordinator,
    ShardedCheckpointer,
    ShardedSaver,
    combine_states,
    participant_wanted,
    spec_overlaps,
)
from repro.configs import get_config
from repro.core import LayerRegistry, Recipe, make_policy, merge
from repro.core.manifest import entry_refs, is_sharded
from repro.core.policies import PolicyContext
from repro.core.recipe import CheckpointRef
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.models.model_api import LayerUnit
from repro.parallel import sharding as shd

SRC = str(Path(__file__).resolve().parent.parent / "src")


# ------------------------------------------------------------- block math
def test_block_math_basics():
    a = ((0, 4), (0, 8))
    b = ((2, 6), (4, 12))
    assert shd.intersect_blocks(a, b) == ((2, 4), (4, 8))
    assert shd.intersect_blocks(a, ((4, 6), (0, 8))) is None
    assert shd.block_size(a) == 32
    assert shd.block_size(()) == 1  # scalar block
    assert shd.blocks_cover_exactly((4, 8), [((0, 2), (0, 8)),
                                             ((2, 4), (0, 8))])
    # overlap -> not a cover
    assert not shd.blocks_cover_exactly((4, 8), [((0, 3), (0, 8)),
                                                 ((2, 4), (0, 8))])
    # hole -> not a cover
    assert not shd.blocks_cover_exactly((4, 8), [((0, 2), (0, 8))])


def test_uniform_blocks_partition_exactly():
    for shape, n in cases(40, lambda rs: (rand_shape(rs, dim_max=13),
                                          int(rs.randint(1, 6)))):
        blocks = [b for pid in range(n)
                  for b in shd.uniform_blocks(shape, pid, n)]
        assert shd.blocks_cover_exactly(shape, blocks), (shape, n, blocks)


def _grid_partition(rs, shape):
    """Random grid tiling of ``shape``: per-dim random cut points ->
    rectangular blocks covering the array exactly."""
    if not shape:
        return [()]
    per_dim = []
    for d in shape:
        n_cuts = rs.randint(0, min(3, d))
        cuts = sorted(set([0, d] + list(rs.randint(1, d, size=n_cuts))
                          if d > 1 else [0, d]))
        per_dim.append([(cuts[i], cuts[i + 1])
                        for i in range(len(cuts) - 1)])
    blocks = [()]
    for ranges in per_dim:
        blocks = [b + (r,) for b in blocks for r in ranges]
    return blocks


def _assign(rs, blocks, k):
    """Distribute blocks over k owners (every block exactly one owner)."""
    owners = [[] for _ in range(k)]
    for b in blocks:
        owners[rs.randint(0, k)].append(b)
    return [tuple(o) for o in owners]


def test_slice_plan_covers_target_exactly():
    """Satellite property: for random global shapes, source shardings
    (random grid tilings grouped into shard objects), and target
    shardings (another random tiling grouped into participants), the
    union of planned reads exactly covers each target participant's
    addressable indices — no holes, no double-reads — and every skipped
    shard is genuinely disjoint from the target."""

    def gen(rs):
        shape = rand_shape(rs, ndim_max=3, dim_max=9)
        n_src = int(rs.randint(1, 5))
        n_tgt = int(rs.randint(1, 5))
        src = _assign(rs, _grid_partition(rs, shape), n_src)
        tgt = _assign(rs, _grid_partition(rs, shape), n_tgt)
        return shape, src, tgt

    for shape, src_shards, tgt_parts in cases(60, gen, seed=77):
        # the source shards must themselves tile the array (sanity on
        # the generator — the same invariant the coordinator checks)
        all_src = [b for s in src_shards for b in s]
        assert shd.blocks_cover_exactly(shape, all_src)
        specs = [{"participant": i,
                  "leaves": [{"path": "w", "shape": list(shape),
                              "dtype": "float32",
                              "blocks": [list(map(list, b))
                                         for b in blocks]}]}
                 for i, blocks in enumerate(src_shards) if blocks]
        for want in tgt_parts:
            def wanted(unit, kind, path, s, _want=want):
                return _want

            planned = [sp for sp in specs
                       if spec_overlaps(sp, wanted, "u", "weights")]
            skipped = [sp for sp in specs if sp not in planned]
            # planned reads cover the wanted region exactly: the
            # intersections tile it (sizes sum; disjoint by source
            # disjointness)
            pieces = []
            for sp in planned:
                for leaf in sp["leaves"]:
                    for b in leaf["blocks"]:
                        blk = tuple((int(x), int(y)) for x, y in b)
                        for w in want:
                            inter = shd.intersect_blocks(blk, w)
                            if inter:
                                pieces.append(inter)
            want_size = sum(shd.block_size(w) for w in want)
            got = sum(shd.block_size(p) for p in pieces)
            assert got == want_size, (shape, want, pieces)
            for i, p in enumerate(pieces):  # no double-reads
                for q in pieces[i + 1:]:
                    assert not shd.intersect_blocks(p, q), (p, q)
            # nothing skipped that overlapped
            for sp in skipped:
                for leaf in sp["leaves"]:
                    for b in leaf["blocks"]:
                        blk = tuple((int(x), int(y)) for x, y in b)
                        for w in want:
                            assert not shd.intersect_blocks(blk, w)


# ------------------------------------------------------- save/restore paths
@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("mamba2-370m", reduced=True)
    model = build_model(cfg)
    state = steps_lib.init_state(model, jax.random.key(0))
    return model, state, LayerRegistry(model)


def _assert_state_equal(a, b, parts=("params", "opt")):
    for key in parts:
        for x, y in zip(jax.tree.leaves(a[key]), jax.tree.leaves(b[key])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sharded_save_restart_restore_roundtrip(small_setup, tmp_path):
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("parity", model.layer_units()))
    ck = ShardedCheckpointer(mgr, 2)
    m1 = ck.save(state, step=10)
    assert all(is_sharded(e) for kinds in m1.entries.values()
               for e in kinds.values())
    assert m1.meta["sharded"]["n_participants"] == 2
    # every shard ref carries a spec whose participant wrote it
    pids = {r.spec["participant"] for kinds in m1.entries.values()
            for e in kinds.values() for r in entry_refs(e)}
    assert pids == {0, 1}
    # unchanged re-save: pure fingerprint dedup, zero payload transfer
    ck.save(state, step=20)
    s = mgr.last_save_stats
    assert s["written_bytes"] == 0 and s["d2h_bytes"] == 0
    assert s["dedup_hits"] > 0
    mgr.close()

    # restart: fresh manager (fp refs cold) restores the chain bit-exact
    mgr2 = CheckpointManager(tmp_path, reg,
                             make_policy("parity", model.layer_units()),
                             async_save=False)
    restored = mgr2.restore(steps_lib.state_specs(model))
    _assert_state_equal(state, restored)
    assert int(restored["step"]) == 20
    assert not mgr2.last_restore_stats["fallback_units"]
    # and a restarted participant still dedups (fp table reloaded from
    # the object envelope)
    ck2 = ShardedCheckpointer(mgr2, 2)
    ck2.save(state, step=30)
    s = mgr2.last_save_stats
    assert s["written_bytes"] == 0 and s["d2h_bytes"] == 0
    mgr2.close()


def test_resharded_restore_reads_strictly_fewer_bytes(small_setup, tmp_path):
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()))
    ck = ShardedCheckpointer(mgr, 2)
    ck.save(state, step=10)
    like = steps_lib.state_specs(model)
    mgr.restore(like)
    full = dict(mgr.last_restore_stats)
    assert full["sharded_targets"] > 0 and full["shards_skipped"] == 0

    results, wanteds = [], []
    for pid in range(4):
        wanted = participant_wanted(reg, pid, 4)
        results.append(mgr.restore(like, owned=wanted))
        s = mgr.last_restore_stats
        assert s["bytes_read"] < full["bytes_read"]
        assert s["shards_skipped"] > 0
        wanteds.append(wanted)
    mgr.close()
    combined = combine_states(like, reg, results, wanteds)
    _assert_state_equal(state, combined)
    assert int(combined["step"]) == 10


def test_block_delta_per_shard_object(tmp_path):
    """Small drift in a big unit takes the BD02 block-sparse delta path
    PER SHARD OBJECT: only the dirty blocks of the owning participant's
    shard move device->host and land as a block delta against that
    shard's own full base."""
    cfg = get_config("llama3.2-3b", reduced=True)
    model = build_model(cfg)
    state = steps_lib.init_state(model, jax.random.key(0))
    reg = LayerRegistry(model)
    # 4 KiB fingerprint blocks: the reduced model's shards span many
    # blocks, so a one-element poke stays under fp_max_dirty_frac.
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            fp_block_bytes=4096)
    ck = ShardedCheckpointer(mgr, 2)
    ck.save(state, step=10)

    def poke(x):
        x = np.array(x)
        x.flat[0] += 1
        return x

    drifted = dict(state)
    drifted["params"] = jax.tree.map(poke, jax.device_get(state["params"]))
    ck.save(drifted, step=20)
    s = mgr.last_save_stats
    assert s["delta_chunks"] > 0, s
    assert s["dirty_block_frac"] < 0.05, s
    assert s["dedup_hits"] > 0  # untouched shards (and all opt) dedup
    restored = mgr.restore(steps_lib.state_specs(model))
    _assert_state_equal(drifted, restored)
    mgr.close()


def test_non_fingerprint_sharded_path(small_setup, tmp_path):
    """The legacy full-gather path also works shard-native (XOR deltas
    per shard object on later events)."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            fingerprint=False)
    ck = ShardedCheckpointer(mgr, 2)
    ck.save(state, step=10)
    drifted = dict(state)
    drifted["params"] = jax.tree.map(lambda x: x + np.ones((), x.dtype),
                                     state["params"])
    ck.save(drifted, step=20)
    assert mgr.last_save_stats["delta_chunks"] > 0, \
        "drifted shard objects should delta-encode against their bases"
    restored = mgr.restore(steps_lib.state_specs(model))
    _assert_state_equal(drifted, restored)
    mgr.close()


def test_sharded_gc_retention(small_setup, tmp_path):
    """Refcounted retention over shard sets: dropped manifests release
    one reference per shard ref (and delta base); objects only die when
    no retained manifest references them."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            keep=2, async_save=False)
    ck = ShardedCheckpointer(mgr, 2, parallel=False)
    rng = np.random.RandomState(0)
    for i in range(4):
        drifted = dict(state)
        drifted["params"] = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x))
            + np.asarray(rng.standard_normal(), np.asarray(x).dtype),
            state["params"])
        ck.save(drifted, step=(i + 1) * 10)
    assert mgr.manifests.all_steps() == [30, 40]
    # every object a retained manifest references is still present...
    live = set()
    for s in (30, 40):
        live |= set(mgr.manifests.load(s).referenced_digests())
    for d in live:
        assert mgr.store.has(d)
    # ...and nothing else survived GC
    on_disk = set(mgr.store.iter_digests())
    assert on_disk == live
    restored = mgr.restore(steps_lib.state_specs(model))
    _assert_state_equal(drifted, restored)
    mgr.close()


def test_barrier_crash_keeps_previous_manifest(small_setup, tmp_path):
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            async_save=False)
    ck = ShardedCheckpointer(mgr, 2)
    ck.save(state, step=10)

    # Crash injection: participant 0 publishes its record for step 20,
    # participant 1 dies before publishing.  The coordinator must refuse
    # and the previous manifest stays authoritative.
    ShardedSaver(mgr, 0, 2).save_shards(state, step=20)
    coord = ShardCoordinator(mgr)
    with pytest.raises(ShardBarrierError, match="missing participant"):
        coord.commit(20, 2)
    restored = mgr.restore(steps_lib.state_specs(model))
    assert int(restored["step"]) == 10
    _assert_state_equal(state, restored)

    # Recovery: the restarted participant re-publishes, commit succeeds.
    ShardedSaver(mgr, 1, 2).save_shards(state, step=20)
    manifest = coord.commit(20, 2)
    assert manifest.step == 20
    restored = mgr.restore(steps_lib.state_specs(model))
    assert int(restored["step"]) == 20
    _assert_state_equal(state, restored)
    mgr.close()


def test_event_index_survives_retention_cap(small_setup, tmp_path):
    """The event counter anchors on the newest manifest's recorded
    index, NOT the retained-manifest count: with keep=2 a parity policy
    must keep alternating halves past the retention horizon (counting
    manifests would saturate at 2 and freeze one half forever)."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("parity", model.layer_units()),
                            keep=2, async_save=False)
    ck = ShardedCheckpointer(mgr, 2, parallel=False)
    selections = []
    for i in range(6):
        m = ck.save(state, step=(i + 1) * 10)
        selections.append((m.meta["event_index"], tuple(m.saved_units)))
    idxs = [i for i, _ in selections]
    assert idxs == list(range(6))
    # consecutive events past the cap still alternate
    assert selections[-1][1] != selections[-2][1]
    # and a restarted manager resumes the counter, not the manifest count
    mgr.close()
    mgr2 = CheckpointManager(tmp_path, reg,
                             make_policy("parity", model.layer_units()),
                             keep=2, async_save=False)
    m = ShardedCheckpointer(mgr2, 2, parallel=False).save(state, step=70)
    assert m.meta["event_index"] == 6
    mgr2.close()


def test_stale_cohort_records_do_not_block_commit(small_setup, tmp_path):
    """Crash-leftover records from a WIDER participant cohort at the
    same step must not block a narrower retry's commit."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            async_save=False)
    # crashed 4-wide attempt: only participants 2 and 3 got to publish
    ShardedSaver(mgr, 2, 4).save_shards(state, step=10)
    ShardedSaver(mgr, 3, 4).save_shards(state, step=10)
    # 2-wide retry at the same step
    ShardedSaver(mgr, 0, 2).save_shards(state, step=10)
    ShardedSaver(mgr, 1, 2).save_shards(state, step=10)
    manifest = ShardCoordinator(mgr).commit(10, 2)
    assert manifest.meta["sharded"]["n_participants"] == 2
    restored = mgr.restore(steps_lib.state_specs(model))
    _assert_state_equal(state, restored)
    mgr.close()


def test_coordinator_rejects_incomplete_cover(small_setup, tmp_path):
    """A shard set with a hole (participant published, but its blocks
    don't tile the unit) must not commit."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            async_save=False)
    # Both participants claim the SAME half -> double cover + hole.
    s0 = ShardedSaver(mgr, 0, 2)
    s1 = ShardedSaver(mgr, 1, 2)
    s1.wanted = s0.wanted  # sabotage: duplicate ownership
    s0.save_shards(state, step=10)
    s1.save_shards(state, step=10)
    with pytest.raises(ShardBarrierError, match="do not exactly tile"):
        ShardCoordinator(mgr).commit(10, 2)
    mgr.close()


def test_shard_fallback_is_unit_consistent(small_setup, tmp_path):
    """When one shard of a unit loses its newest object, the WHOLE unit
    falls back to the newest step every shard can serve — a tensor must
    never assemble from mixed manifest steps (a state that never
    existed)."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            keep=4, async_save=False)
    ck = ShardedCheckpointer(mgr, 2, parallel=False)
    ck.save(state, step=10)
    drifted = dict(state)
    drifted["params"] = jax.tree.map(
        lambda x: np.asarray(jax.device_get(x))
        + np.ones((), np.asarray(x).dtype),
        state["params"])
    ck.save(drifted, step=20)

    unit = reg.unit_names()[0]
    m20 = mgr.manifests.load(20)
    victim = entry_refs(m20.entries[unit]["weights"])[0]
    assert victim.step == 20  # drift produced a fresh step-20 object
    # simulate storage loss of participant 0's newest weights shard;
    # its delta base (if any) stays, so per-shard fallback WOULD succeed
    mgr.store.object_path(victim.digest).unlink()

    restored = mgr.restore(steps_lib.state_specs(model))
    s = mgr.last_restore_stats
    assert s["fallback_units"].get(f"{unit}/weights") == 10
    # the damaged unit's weights are ENTIRELY step-10 content (both
    # shards aligned), not a mix of step-10 and step-20 halves
    got = reg.extract_unit(restored["params"], unit)
    want = reg.extract_unit(state["params"], unit)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # undamaged units restore at step 20
    other = reg.unit_names()[1]
    for a, b in zip(
            jax.tree.leaves(reg.extract_unit(drifted["params"], other)),
            jax.tree.leaves(reg.extract_unit(restored["params"], other))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


def test_shard_fallback_spans_dedup_steps(small_setup, tmp_path):
    """An unchanged shard's entry dedups to the same digest across
    steps, so one object serves several steps: aligning a unit on an
    older step must succeed when the other shard's content is identical
    at both steps (no false mixed-step error, no data loss)."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            keep=4, async_save=False)
    ck = ShardedCheckpointer(mgr, 2, parallel=False)
    ck.save(state, step=10)
    unit = reg.unit_names()[0]

    # drift ONLY participant 1's half (lower axis-0 rows) of one unit's
    # leaves: p0's shard then dedups at step 20 (same digest as step 10)
    def poke_lower(x):
        out = np.asarray(x).copy()
        out[out.shape[0] // 2:] += np.ones((), out.dtype)
        return out

    params = jax.device_get(state["params"])
    drifted = dict(state)
    drifted["params"] = reg.insert_unit(
        params, unit,
        jax.tree.map(poke_lower, reg.extract_unit(params, unit)))
    ck.save(drifted, step=20)

    m20 = mgr.manifests.load(20)
    refs = entry_refs(m20.entries[unit]["weights"])
    by_pid = {r.spec["participant"]: r for r in refs}
    m10 = mgr.manifests.load(10)
    refs10 = {r.spec["participant"]: r
              for r in entry_refs(m10.entries[unit]["weights"])}
    assert by_pid[0].digest == refs10[0].digest, "p0 shard must dedup"
    assert by_pid[1].digest != refs10[1].digest
    mgr.store.object_path(by_pid[1].digest).unlink()

    restored = mgr.restore(steps_lib.state_specs(model))
    # aligned on step 10: the whole unit is step-10 content (p0's half
    # was identical at both steps anyway)
    got = reg.extract_unit(restored["params"], unit)
    for a, b in zip(jax.tree.leaves(reg.extract_unit(params, unit)),
                    jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.last_restore_stats["fallback_units"].get(
        f"{unit}/weights") == 10
    mgr.close()


def test_shard_fallback_refuses_mixed_step_tensor(small_setup, tmp_path):
    """When NO single manifest step is readable by every shard of a
    unit, restore must fail loudly instead of assembling a tensor that
    never existed."""
    from repro.checkpoint.restore import RestoreError

    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()),
                            keep=4, async_save=False)
    ck = ShardedCheckpointer(mgr, 2, parallel=False)
    ck.save(state, step=10)
    drifted = dict(state)
    drifted["params"] = jax.tree.map(
        lambda x: np.asarray(jax.device_get(x))
        + np.ones((), np.asarray(x).dtype),
        state["params"])  # every block dirty -> full objects, no deltas
    ck.save(drifted, step=20)

    unit = reg.unit_names()[0]
    p20 = {r.spec["participant"]: r for r in entry_refs(
        mgr.manifests.load(20).entries[unit]["weights"])}
    p10 = {r.spec["participant"]: r for r in entry_refs(
        mgr.manifests.load(10).entries[unit]["weights"])}
    # p1 can only serve step 10, p0 can only serve step 20
    mgr.store.object_path(p20[1].digest).unlink()
    mgr.store.object_path(p10[0].digest).unlink()
    with pytest.raises(RestoreError, match="mixed-step"):
        mgr.restore(steps_lib.state_specs(model))
    mgr.close()


def test_sharded_save_over_legacy_manifest_forces_full(small_setup,
                                                       tmp_path):
    """A pre-content-addressing previous manifest (digest-less refs)
    cannot be carried forward: the sharded event must select every unit
    and commit a fresh, fully-restorable shard manifest."""
    from repro.checkpoint.chunk_store import ChunkRef
    from repro.core.manifest import Manifest

    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("parity", model.layer_units()),
                            async_save=False)
    ShardedCheckpointer(mgr, 2, parallel=False).save(state, step=10)
    # hack a legacy manifest on top: one unit's ref has no digest
    m = mgr.manifests.load(10)
    unit = reg.unit_names()[0]
    legacy = {u: dict(k) for u, k in m.entries.items()}
    legacy[unit]["weights"] = ChunkRef(
        step=20, unit=unit, kind="weights",
        relpath="step-20/old.chunk", nbytes=0, digest="")
    mgr.manifests.commit(Manifest(step=20, entries=legacy, meta={}))

    mgr2 = CheckpointManager(tmp_path, reg,
                             make_policy("parity", model.layer_units()),
                             async_save=False)
    ck = ShardedCheckpointer(mgr2, 2, parallel=False)
    m30 = ck.save(state, step=30)
    # full selection despite the parity policy, and no digest-less refs
    assert set(m30.saved_units) == set(reg.unit_names())
    assert all(r.digest for kinds in m30.entries.values()
               for e in kinds.values() for r in entry_refs(e))
    restored = mgr2.restore(steps_lib.state_specs(model), step=30)
    _assert_state_equal(state, restored)
    mgr2.close()
    mgr.close()


def test_merge_copies_shard_sets_atomically(small_setup, tmp_path):
    model, state, reg = small_setup
    src = tmp_path / "src"
    mgr = CheckpointManager(src, reg,
                            make_policy("full", model.layer_units()))
    ck = ShardedCheckpointer(mgr, 2)
    ck.save(state, step=10)
    recipe = Recipe(base=CheckpointRef(src, 10),
                    output=tmp_path / "out", select=[])
    stats = merge(recipe, workers=2,
                  stores={str(CheckpointRef(src, 10)): mgr.store})
    mgr.close()
    assert stats["chunks"] > len(reg.units), \
        "sharded entries contribute one copied object per shard"

    mgr2 = CheckpointManager(tmp_path / "out", reg,
                             make_policy("full", model.layer_units()),
                             async_save=False)
    m = mgr2.manifests.load()
    assert all(is_sharded(e) for kinds in m.entries.values()
               for e in kinds.values())
    restored = mgr2.restore(steps_lib.state_specs(model))
    _assert_state_equal(state, restored)
    mgr2.close()


def test_global_save_over_sharded_chain(small_setup, tmp_path):
    """A classic CheckpointManager.save on top of a sharded manifest
    writes fresh global entries (no cross-layout delta) and restores."""
    model, state, reg = small_setup
    mgr = CheckpointManager(tmp_path, reg,
                            make_policy("full", model.layer_units()))
    ShardedCheckpointer(mgr, 2).save(state, step=10)
    mgr.save(state, step=20)
    m = mgr.manifests.load()
    assert not any(is_sharded(e) for kinds in m.entries.values()
                   for e in kinds.values())
    restored = mgr.restore(steps_lib.state_specs(model))
    _assert_state_equal(state, restored)
    mgr.close()


# ------------------------------------------------------------ policy satellite
def _mk_units(n):
    return ([LayerUnit(name=f"block_{i:02d}", path=("blocks",), index=i)
             for i in range(n)]
            + [LayerUnit(name="embed", path=("embed",), kind="aux"),
               LayerUnit(name="final_norm", path=("norm",), kind="aux")])


def test_topk_delta_tie_break_is_deterministic():
    """Equal drift scores must select the FIRST k blocks in registry
    order, independent of the iteration order drift_scores was built in
    (reproducible selections across runs and across the participants of
    one sharded save event)."""
    units = _mk_units(6)
    pol = make_policy("topk_delta", units, frac=0.5)
    blocks = pol.blocks
    tied = {b: 1.0 for b in blocks}
    reversed_insert = {b: 1.0 for b in reversed(blocks)}
    ctx = PolicyContext(event_index=3, step=0, drift_scores=tied)
    ctx_r = PolicyContext(event_index=3, step=0,
                          drift_scores=reversed_insert)
    sel = [u for u in pol.select(ctx) if u.startswith("block")]
    sel_r = [u for u in pol.select(ctx_r) if u.startswith("block")]
    assert sel == sel_r == blocks[:3]
    # partial tie below the cut: the tied tail breaks by block order too
    scores = {b: (2.0 if i == 4 else 1.0) for i, b in enumerate(blocks)}
    sel = [u for u in pol.select(PolicyContext(0, 0, drift_scores=scores))
           if u.startswith("block")]
    assert sel == [blocks[4], blocks[0], blocks[1]]


# ----------------------------------------------------------- mesh subprocess
def test_mesh_sharded_save_and_resharded_restore():
    """Acceptance: save on a 1x8 mesh with 2 participants, restore on a
    2x4 mesh as 4 participants — bit-exact after stitching, and every
    restore participant reads strictly fewer bytes than the full-array
    restore of the same manifest."""
    code = """
        import tempfile, jax, numpy as np
        from pathlib import Path
        from repro.configs import get_config
        from repro.core import LayerRegistry, make_policy
        from repro.checkpoint.saver import CheckpointManager
        from repro.checkpoint.sharded import (ShardedCheckpointer,
                                              participant_wanted,
                                              combine_states)
        from repro.launch import steps as steps_lib
        from repro.launch.mesh import make_debug_mesh
        from repro.models import build_model

        cfg = get_config("llama3.2-3b", reduced=True)
        model = build_model(cfg)
        tmp = Path(tempfile.mkdtemp())
        reg = LayerRegistry(model)
        mesh_save = make_debug_mesh(1, 8)
        sh = steps_lib.state_shardings(model, mesh_save)
        state = steps_lib.init_state(model, jax.random.key(0))
        state = jax.tree.map(jax.device_put, state, sh)
        mgr = CheckpointManager(tmp, reg,
                                make_policy("full", model.layer_units()))
        ShardedCheckpointer(mgr, 2, shardings=sh).save(state, step=7)
        like = steps_lib.state_specs(model)
        mgr.restore(like)
        full_bytes = mgr.last_restore_stats["bytes_read"]
        mgr.close()

        mesh_r = make_debug_mesh(2, 4)
        sh_r = steps_lib.state_shardings(model, mesh_r)
        mgr2 = CheckpointManager(tmp, reg,
                                 make_policy("full", model.layer_units()),
                                 async_save=False)
        results, wanteds = [], []
        for pid in range(4):
            w = participant_wanted(reg, pid, 4, shardings=sh_r)
            results.append(mgr2.restore(like, shardings=sh_r, owned=w))
            s = mgr2.last_restore_stats
            assert s["bytes_read"] < full_bytes, (s["bytes_read"],
                                                  full_bytes)
            assert s["shards_skipped"] > 0
            wanteds.append(w)
        mgr2.close()
        comb = combine_states(like, reg, results, wanteds)
        for key in ("params", "opt"):
            for a, b in zip(jax.tree.leaves(state[key]),
                            jax.tree.leaves(comb[key])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(comb["step"]) == 7
        print("OK")
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
