"""Serving fleet: delta-push hot-swap (checkpoint/swap.py), the
digest-keyed BlockCache (checkpoint/block_cache.py), zero-copy variant
manifests (core.tailor.variant_manifest), and concurrent fleet restore
from one store.  See docs/serving.md."""
import glob
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import faults
from repro.checkpoint.block_cache import BlockCache
from repro.checkpoint.faults import InjectedCrash
from repro.checkpoint.saver import CheckpointManager
from repro.checkpoint.swap import VariantSet, WeightService, _entry_key
from repro.configs import get_config
from repro.core import LayerRegistry, make_policy
from repro.core.tailor import MergeError, variant_manifest
from repro.launch import steps as steps_lib
from repro.models import build_model

ARCH = "mamba2-370m"


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.disarm()
    yield
    faults.disarm()


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH, reduced=True)
    model = build_model(cfg)
    state1 = steps_lib.init_state(model, jax.random.key(0))

    def poke(x):
        x = np.array(x)
        x.flat[:1] += 1
        return x

    # Every weight leaf drifts by one element: with 4 KiB fingerprint
    # blocks the second event lands as block-sparse deltas, the exact
    # shape the scatter fast path exists for.
    state2 = {"step": np.array(state1["step"]),
              "params": jax.tree.map(poke, state1["params"]),
              "opt": jax.tree.map(poke, state1["opt"])}
    return model, LayerRegistry(model), state1, state2


def _mgr(root, registry, model, **kw):
    kw.setdefault("async_save", False)
    kw.setdefault("fp_block_bytes", 4096)
    return CheckpointManager(root, registry,
                             make_policy("full", model.layer_units()), **kw)


def _assert_params_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ hot-swap core
@pytest.mark.parametrize("backend", ["local", "tiered", "remote3"])
def test_swap_parity_bit_exact(tmp_path, setup, backend):
    """Swap-vs-cold-restore parity on every store composition: load step
    10, hot-swap to 20, compare bit-exact against a cold restore of 20."""
    model, reg, s1, s2 = setup
    kw = {"store_backend": backend}
    if backend == "remote3":
        kw["remote_opts"] = {"latency": 0.0, "seed": 7}
    mgr = _mgr(tmp_path, reg, model, **kw)
    try:
        mgr.save(s1, step=10)
        mgr.save(s2, step=20)
        like = steps_lib.state_specs(model)
        svc = WeightService(mgr, like, step=10)
        assert svc.step == 10
        stats = svc.poll()
        assert stats is not None and svc.step == 20
        assert stats["units_swapped"] > 0
        cold = mgr.restore(like, parts=("params",), step=20)
        _assert_params_equal(svc.current(), cold["params"])
        # promotion must transfer drift, not model size
        assert stats["bytes_read"] < mgr.last_restore_stats["bytes_read"]
    finally:
        mgr.close()


def test_swap_scatter_is_dirty_block_sized(tmp_path, setup):
    """The BD02 scatter path: swapped bytes/H2D scale with dirty blocks,
    unchanged units are zero-read zero-H2D, and a repeat poll no-ops."""
    model, reg, s1, s2 = setup
    mgr = _mgr(tmp_path, reg, model)
    try:
        mgr.save(s1, step=10)
        # Drift exactly one unit; everything else dedups to step 10.
        unit = model.layer_units()[1].name
        p2 = dict(s1["params"])
        sub = reg.extract_unit(s1["params"], unit)
        poked = jax.tree.map(lambda x: np.array(x), sub)
        for leaf in jax.tree.leaves(poked):
            leaf.flat[:1] += 1
        mgr.save({"step": s1["step"],
                  "params": reg.insert_unit(p2, unit, poked),
                  "opt": s1["opt"]}, step=20)
        like = steps_lib.state_specs(model)
        svc = WeightService(mgr, like, step=10)
        stats = svc.poll()
        n_units = len(model.layer_units())
        assert stats["units_swapped"] == 1
        assert stats["units_skipped"] == n_units - 1
        assert stats["units_scattered"] == 1 and stats["units_full"] == 0
        total = sum(np.asarray(x).nbytes
                    for x in jax.tree.leaves(svc.current()))
        assert 0 < stats["h2d_bytes"] < total // 10
        assert stats["blocks_applied"] > 0
        cold = mgr.restore(like, parts=("params",), step=20)
        _assert_params_equal(svc.current(), cold["params"])
        # already current: poll is a pure no-op (not even a manifest load)
        assert svc.poll() is None
    finally:
        mgr.close()


def test_swap_across_skipped_manifests(tmp_path, setup):
    """Delta-chain promotion across several manifests the server never
    saw: 10 -> 40 in one swap, parity with a cold restore of 40."""
    model, reg, s1, _ = setup
    mgr = _mgr(tmp_path, reg, model)
    try:
        state = s1
        mgr.save(state, step=10)
        for step in (20, 30, 40):
            state = {"step": state["step"],
                     "params": jax.tree.map(
                         lambda x: np.array(x) + np.ones(1, np.asarray(
                             x).dtype), state["params"]),
                     "opt": state["opt"]}
            mgr.save(state, step=step)
        like = steps_lib.state_specs(model)
        svc = WeightService(mgr, like, step=10)
        stats = svc.poll()
        assert stats["step_from"] == 10 and stats["step_to"] == 40
        cold = mgr.restore(like, parts=("params",), step=40)
        _assert_params_equal(svc.current(), cold["params"])
    finally:
        mgr.close()


def test_swap_rollback_to_older_manifest(tmp_path, setup):
    """Demotion is promotion backwards: pointing LATEST at an older step
    swaps the fleet back bit-exact (digest diff, not step arithmetic)."""
    model, reg, s1, s2 = setup
    mgr = _mgr(tmp_path, reg, model)
    try:
        mgr.save(s1, step=10)
        mgr.save(s2, step=20)
        like = steps_lib.state_specs(model)
        svc = WeightService(mgr, like, step=20)
        # roll LATEST back to 10 (what an operator rollback does)
        m10 = mgr.manifests.load(10)
        mgr.manifests.commit(m10)
        stats = svc.poll()
        assert stats["step_to"] == 10
        cold = mgr.restore(like, parts=("params",), step=10)
        _assert_params_equal(svc.current(), cold["params"])
    finally:
        mgr.close()


def test_swap_apply_crash_leaves_old_weights_serving(tmp_path, setup):
    """The swap_apply drill: a crash mid-swap must leave the previous
    weights served (never a half-applied tensor) and the next poll must
    complete the identical swap cleanly."""
    model, reg, s1, s2 = setup
    mgr = _mgr(tmp_path, reg, model)
    try:
        mgr.save(s1, step=10)
        mgr.save(s2, step=20)
        like = steps_lib.state_specs(model)
        svc = WeightService(mgr, like, step=10)
        before = svc.current()
        served_before = dict(svc._served)
        # hit=2: die on the SECOND changed unit — some units already
        # staged, none may be published.
        with faults.scoped("swap_apply", hit=2):
            with pytest.raises(InjectedCrash):
                svc.poll()
        assert svc.step == 10
        assert svc._served == served_before
        _assert_params_equal(svc.current(), before)
        cold10 = mgr.restore(like, parts=("params",), step=10)
        _assert_params_equal(svc.current(), cold10["params"])
        # recovery: the next poll redoes the whole swap (idempotent diff)
        stats = svc.poll()
        assert stats is not None and svc.step == 20
        cold20 = mgr.restore(like, parts=("params",), step=20)
        _assert_params_equal(svc.current(), cold20["params"])
    finally:
        mgr.close()


# ------------------------------------------------------------- block cache
def test_block_cache_lru_budget_and_eviction():
    c = BlockCache(100)
    reads = {"n": 0}

    def loader(blob):
        def go():
            reads["n"] += 1
            return blob
        return go

    assert c.get("a", loader(b"a" * 40)) == b"a" * 40
    assert c.get("b", loader(b"b" * 40)) == b"b" * 40
    assert c.get("a", loader(b"a" * 40)) == b"a" * 40  # hit, refreshes LRU
    assert reads["n"] == 2
    # 40+40+40 > 100: evicts the LRU entry, which is now "b"
    c.get("c", loader(b"c" * 40))
    snap = c.snapshot()
    assert snap["evictions"] == 1
    assert c.peek("a") and c.peek("c") and not c.peek("b")
    # oversized entries bypass instead of wiping the cache
    c.get("huge", loader(b"x" * 500))
    snap = c.snapshot()
    assert snap["bypassed"] == 1 and snap["entries"] == 2
    # a failed load is NOT memoized: the next get retries and succeeds
    with pytest.raises(RuntimeError):
        c.get("flaky", (lambda: (_ for _ in ()).throw(RuntimeError("io"))))
    assert c.get("flaky", loader(b"f")) == b"f"


def test_block_cache_coalesces_concurrent_misses():
    c = BlockCache(1 << 20)
    started = threading.Event()
    release = threading.Event()
    loads = {"n": 0}

    def slow_loader():
        loads["n"] += 1
        started.set()
        release.wait(5)
        return b"payload"

    results = []

    def get():
        results.append(c.get("d", slow_loader))

    threads = [threading.Thread(target=get) for _ in range(4)]
    threads[0].start()
    assert started.wait(5)
    for t in threads[1:]:
        t.start()
    time.sleep(0.05)  # let the followers reach the wait
    release.set()
    for t in threads:
        t.join(5)
    assert loads["n"] == 1
    assert results == [b"payload"] * 4
    snap = c.snapshot()
    assert snap["misses"] == 1 and snap["coalesced"] >= 1


def test_block_cache_shm_segments_cleaned():
    """shm=True entries live under the repo-wide repro-io-<pid>- prefix
    (one glob covers worker arenas, staging slots, AND cache segments)
    and close() unlinks them — the conftest leak guard enforces this
    for every test in the session."""
    pattern = f"/dev/shm/repro-io-{os.getpid():x}-cache-*"
    c = BlockCache(1 << 20, shm=True)
    c.get("a", lambda: b"x" * 128)
    assert glob.glob(pattern)
    assert c.get("a", lambda: b"never") == b"x" * 128
    c.close()
    assert not glob.glob(pattern)


def test_store_reads_through_cache_and_gc_discards(tmp_path, setup):
    """ChunkStore._backend_read consults the cache (second manager-level
    read of one digest never touches the backend) and gc drops deleted
    digests from the cache."""
    model, reg, s1, _ = setup
    cache = BlockCache(64 << 20)
    # full objects only: a delta would pin its step-10 base past the gc
    mgr = _mgr(tmp_path, reg, model, block_cache=cache, keep=1,
               delta=False, fingerprint=False)
    try:
        mgr.save(s1, step=10)
        digest = next(iter(mgr.manifests.load(10).referenced_digests()))
        mgr.store.read_object_bytes(digest)
        before = mgr.store.backend_reads
        mgr.store.read_object_bytes(digest)
        assert mgr.store.backend_reads == before  # served from cache
        assert cache.peek(digest)
        # retire the manifest; gc must evict its digests from the cache
        poked = {"step": s1["step"],
                 "params": jax.tree.map(lambda x: np.array(x) + 1,
                                        s1["params"]),
                 "opt": s1["opt"]}
        mgr.save(poked, step=20)
        mgr.gc()
        assert not mgr.store.has(digest)
        assert not cache.peek(digest)
    finally:
        mgr.close()
        cache.close()


# ---------------------------------------------------------------- variants
def test_variant_manifest_expansion_and_errors(tmp_path, setup):
    model, reg, s1, s2 = setup
    mgr = _mgr(tmp_path, reg, model)
    try:
        mgr.save(s1, step=10)
        mgr.save(s2, step=20)
        units = [u.name for u in model.layer_units()]
        blocks = [u for u in units if u.startswith("block_")]
        m = variant_manifest(
            mgr.manifests, base_step=20,
            select=[(f"{blocks[0]}..{blocks[-1]}", 10)], name="v")
        assert m.step == 20
        assert m.meta["variant"]["name"] == "v"
        m10, m20 = mgr.manifests.load(10), mgr.manifests.load(20)
        for u in units:
            want = m10 if u in blocks else m20
            assert _entry_key(m.entries[u]["weights"]) \
                == _entry_key(want.entries[u]["weights"])
        with pytest.raises(KeyError):
            variant_manifest(mgr.manifests, base_step=20,
                             select=[("no_such_unit", 10)])
        with pytest.raises(MergeError):
            variant_manifest(mgr.manifests, base_step=20,
                             select=[(blocks[0], 999)])
    finally:
        mgr.close()


def test_variants_share_digest_reads_through_cache(tmp_path, setup):
    """K variants behind one BlockCache read each shared digest off the
    backend exactly once (spying on the backend read layer)."""
    model, reg, s1, s2 = setup
    mgr = _mgr(tmp_path, reg, model, block_cache_bytes=64 << 20)
    try:
        mgr.save(s1, step=10)
        mgr.save(s2, step=20)
        seen = []
        real = mgr.store.backend.read

        def spy(digest):
            seen.append(digest)
            return real(digest)

        mgr.store.backend.read = spy
        like = steps_lib.state_specs(model)
        units = [u.name for u in model.layer_units()]
        vs = VariantSet(mgr, like)
        vs.materialize("a", base_step=20)
        vs.materialize("b", base_step=20, select=[(units[0], 10)])
        vs.materialize("c", base_step=20, select=[(units[-1], 10)])
        assert len(seen) == len(set(seen)), \
            f"digest read more than once across variants: {seen}"
        cache = mgr.block_cache.snapshot()
        assert cache["hits"] > 0
        assert cache["misses"] == len(set(seen))
        # parity: variant b's overridden unit serves step-10 content
        cold10 = mgr.restore(like, parts=("params",), step=10)
        _assert_params_equal(
            reg.extract_unit(vs.params("b"), units[0]),
            reg.extract_unit(cold10["params"], units[0]))
    finally:
        mgr.store.backend.read = real
        mgr.close()


def test_uncached_variants_read_more(tmp_path, setup):
    """The bench gate's property at test scale: 3 uncached loads issue
    strictly more backend reads than 3 cached loads from one store."""
    model, reg, s1, s2 = setup
    like = steps_lib.state_specs(model)
    units = [u.name for u in model.layer_units()]
    selects = [(), [(units[0], 10)], [(units[-1], 10)]]

    def load_k(root, cache_bytes):
        mgr = _mgr(root, reg, model, block_cache_bytes=cache_bytes)
        try:
            mgr.save(s1, step=10)
            mgr.save(s2, step=20)
            base = mgr.store.backend_reads
            vs = VariantSet(mgr, like)
            for i, sel in enumerate(selects):
                vs.materialize(f"v{i}", base_step=20, select=sel)
            return mgr.store.backend_reads - base
        finally:
            mgr.close()

    cached = load_k(tmp_path / "cached", 64 << 20)
    uncached = load_k(tmp_path / "uncached", None)
    assert cached < uncached


# ------------------------------------------------------------ fleet restore
def test_concurrent_fleet_restore_one_store(tmp_path, setup):
    """Several server 'replicas' (one manager each, same root) restoring
    concurrently from one store all land bit-exact."""
    model, reg, s1, _ = setup
    writer = _mgr(tmp_path, reg, model)
    writer.save(s1, step=10)
    writer.close()
    like = steps_lib.state_specs(model)
    ref = None
    results = [None] * 3
    errors = []

    def replica(i):
        try:
            m = _mgr(tmp_path, reg, model)
            try:
                st = m.restore(like, parts=("params",), step=10)
                results[i] = st["params"]
            finally:
                m.close()
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=replica, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert not errors, errors
    ref_mgr = _mgr(tmp_path, reg, model)
    try:
        ref = ref_mgr.restore(like, parts=("params",), step=10)["params"]
    finally:
        ref_mgr.close()
    for got in results:
        assert got is not None
        _assert_params_equal(got, ref)
