"""Incremental checkpoint subsystem: content-addressed dedup, sparse-XOR
delta encoding, refcounted GC, and their end-to-end composition through
CheckpointManager and the explicit merge engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases, rand_shape

from repro.checkpoint import ChunkStore
from repro.checkpoint import compression
from repro.checkpoint.chunk_store import content_digest
from repro.checkpoint.saver import CheckpointManager
from repro.configs import get_config
from repro.core import (
    CheckpointRef,
    LayerRegistry,
    ManifestStore,
    Recipe,
    SelectRule,
    make_policy,
    merge,
)
from repro.launch import steps as steps_lib
from repro.models import build_model


# -------------------------------------------------------------- delta codec
def test_delta_codec_roundtrip_property():
    def gen(rs):
        base = rs.bytes(int(rs.randint(1, 5000)))
        cur = bytearray(base)
        # random sparse mutations, possibly resizing
        for _ in range(rs.randint(0, 8)):
            if cur:
                cur[rs.randint(0, len(cur))] ^= 1 + rs.randint(0, 255)
        if rs.rand() < 0.3:
            cur += rs.bytes(int(rs.randint(0, 100)))
        elif rs.rand() < 0.3 and len(cur) > 1:
            del cur[len(cur) // 2:]
        return bytes(cur), base

    for cur, base in cases(24, gen):
        blob = compression.delta_encode(cur, base)
        assert compression.is_delta(blob)
        assert compression.delta_decode(blob, base) == cur


def test_delta_codec_sparse_change_is_small():
    base = bytes(100_000)
    cur = bytearray(base)
    cur[5000:5010] = b"0123456789"
    blob = compression.delta_encode(bytes(cur), base)
    assert len(blob) < 200  # one tiny segment, not 100 KB
    assert compression.delta_decode(blob, base) == bytes(cur)


def test_delta_codec_identical_payloads():
    base = np.random.RandomState(0).bytes(4096)
    blob = compression.delta_encode(base, base)
    assert compression.delta_decode(blob, base) == base
    assert len(blob) < 64


# ------------------------------------------------------- store-level dedup
def test_same_payload_twice_one_object_refcount_two(tmp_path):
    store = ChunkStore(tmp_path)
    tree = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64)}
    r1 = store.write(10, "block_000", "weights", tree)
    r2 = store.write(20, "block_000", "weights", tree)
    # same content => same digest, ONE object on disk, second write free
    assert r1.digest == r2.digest
    assert len(list((tmp_path / "objects").glob("*/*.chunk"))) == 1
    assert store.stats["dedup_hits"] == 1
    assert store.stats["full_chunks"] == 1
    # two manifests would each hold a reference
    store.incref([r1.digest])
    store.incref([r2.digest])
    assert store.refcount(r1.digest) == 2
    # refs differ only in provenance, not content
    assert (r1.step, r2.step) == (10, 20)
    assert r1.relpath == r2.relpath


def test_dedup_is_unit_independent(tmp_path):
    """Two different units with identical tensors share one object."""
    store = ChunkStore(tmp_path)
    tree = {"w": np.ones((32, 32), np.float32)}
    r1 = store.write(1, "block_000", "weights", tree)
    r2 = store.write(1, "block_007", "weights", tree)
    assert r1.digest == r2.digest
    assert len(list((tmp_path / "objects").glob("*/*.chunk"))) == 1


# ------------------------------------------------------- store-level delta
def test_delta_chunk_roundtrip_byte_identical(tmp_path):
    store = ChunkStore(tmp_path)
    rs = np.random.RandomState(3)
    base_tree = {"w": rs.standard_normal((128, 64)).astype(np.float32),
                 "b": rs.standard_normal(64).astype(np.float32)}
    r_full = store.write(1, "u", "weights", base_tree)
    assert r_full.stored == "full"

    cur_tree = {"w": base_tree["w"].copy(), "b": base_tree["b"].copy()}
    cur_tree["w"][3, :5] += 1.0  # sparse drift
    r_delta = store.write(2, "u", "weights", cur_tree,
                          delta_base=r_full.digest)
    assert r_delta.stored == "delta"
    assert r_delta.delta_base == r_full.digest
    assert r_delta.nbytes < r_full.nbytes / 4

    out, _ = store.read(r_delta)
    np.testing.assert_array_equal(out["w"], cur_tree["w"])
    np.testing.assert_array_equal(out["b"], cur_tree["b"])
    # canonical payload reconstructs bit-exactly => digest verifies
    assert content_digest(store.read_canonical(r_delta.digest)) \
        == r_delta.digest


def test_delta_chain_stays_depth_one_and_rebases(tmp_path):
    """Successive deltas all point at the same FULL object, and after
    rebase_every consecutive deltas the store forces a full rebase."""
    store = ChunkStore(tmp_path, rebase_every=4)
    tree = {"w": np.zeros((256,), np.float32)}
    refs = [store.write(0, "u", "weights", tree)]
    for i in range(1, 6):
        tree = {"w": tree["w"].copy()}
        tree["w"][i] = float(i)
        refs.append(store.write(i, "u", "weights", tree,
                                delta_base=refs[-1].digest))
    assert refs[0].stored == "full"
    for r in refs[1:5]:
        assert r.stored == "delta"
        assert r.delta_base == refs[0].digest  # never a delta-of-delta
    # 5th consecutive delta candidate is forced full: one base object must
    # not underpin an unbounded run of checkpoints
    assert refs[5].stored == "full"
    out, _ = store.read(refs[-1])
    np.testing.assert_array_equal(out["w"], tree["w"])
    # the rebased full becomes the next chain's base
    tree2 = {"w": tree["w"].copy()}
    tree2["w"][7] = 7.0
    r = store.write(6, "u", "weights", tree2, delta_base=refs[5].digest)
    assert r.stored == "delta" and r.delta_base == refs[5].digest


def test_dense_change_falls_back_to_full(tmp_path):
    """When every byte drifts, a delta cannot win; the store rebases."""
    store = ChunkStore(tmp_path)
    rs = np.random.RandomState(7)
    t1 = {"w": rs.standard_normal((64, 64)).astype(np.float32)}
    r1 = store.write(1, "u", "weights", t1)
    t2 = {"w": (t1["w"] * 1.7).astype(np.float32)}
    r2 = store.write(2, "u", "weights", t2, delta_base=r1.digest)
    assert r2.stored == "full"
    assert r2.delta_base is None


def test_lossy_codec_never_delta_encodes(tmp_path):
    store = ChunkStore(tmp_path, codec="int8")
    rs = np.random.RandomState(9)
    t1 = {"w": rs.standard_normal((512, 8)).astype(np.float32)}
    r1 = store.write(1, "u", "weights", t1)
    t2 = {"w": t1["w"].copy()}
    t2["w"][0, 0] += 1.0
    r2 = store.write(2, "u", "weights", t2, delta_base=r1.digest)
    assert r2.stored == "full"


# ---------------------------------------------------------------------- gc
def test_gc_frees_only_unreferenced_digests(tmp_path):
    store = ChunkStore(tmp_path)
    shared = store.write(1, "a", "weights", {"w": np.ones(64, np.float32)})
    only1 = store.write(1, "b", "weights", {"w": np.full(64, 2.0, np.float32)})
    only2 = store.write(2, "b", "weights", {"w": np.full(64, 3.0, np.float32)})
    # manifest 1 refs {shared, only1}; manifest 2 refs {shared, only2}
    store.incref([shared.digest, only1.digest])
    store.incref([shared.digest, only2.digest])
    assert store.gc_objects() == 0  # everything referenced

    # drop manifest 1
    store.decref([shared.digest, only1.digest])
    freed = store.gc_objects()
    assert freed == only1.nbytes
    assert not store.has(only1.digest)
    assert store.has(shared.digest) and store.has(only2.digest)
    assert store.refcount(shared.digest) == 1


def test_gc_keeps_delta_base_alive(tmp_path):
    """A full object outlives its own manifest while a delta needs it."""
    store = ChunkStore(tmp_path)
    t1 = {"w": np.zeros(1024, np.float32)}
    r1 = store.write(1, "u", "weights", t1)
    t2 = {"w": t1["w"].copy()}
    t2["w"][0] = 1.0
    r2 = store.write(2, "u", "weights", t2, delta_base=r1.digest)
    assert r2.stored == "delta"
    # manifest 1: {r1}; manifest 2: {r2 + its base r1}
    store.incref([r1.digest])
    store.incref([r2.digest, r2.delta_base])
    store.decref([r1.digest])  # manifest 1 dropped
    assert store.gc_objects() == 0
    assert store.has(r1.digest)  # pinned by the delta
    out, _ = store.read(r2)
    np.testing.assert_array_equal(out["w"], t2["w"])
    # dropping manifest 2 releases both
    store.decref([r2.digest, r2.delta_base])
    assert store.gc_objects() > 0
    assert not store.has(r1.digest) and not store.has(r2.digest)


def test_gc_sweeps_orphans(tmp_path):
    """Objects never referenced by a manifest (crash mid-save) are swept."""
    store = ChunkStore(tmp_path)
    ref = store.write(1, "u", "weights", {"w": np.ones(16, np.float32)})
    assert store.gc_objects() == ref.nbytes
    assert not store.has(ref.digest)


def test_gc_sweeps_stale_tmp_files(tmp_path):
    """Crash-leftover _atomic_write tmp files are reclaimed by gc."""
    store = ChunkStore(tmp_path)
    ref = store.write(1, "u", "weights", {"w": np.ones(16, np.float32)})
    store.incref([ref.digest])
    stale = store.object_path(ref.digest).with_suffix(".chunk.tmp-dead-1")
    stale.write_bytes(b"x" * 100)
    assert store.gc_objects() == 100
    assert not stale.exists() and store.has(ref.digest)


def test_concurrent_identical_writes_dedup(tmp_path):
    """Writer threads persisting bitwise-identical units produce one write
    plus dedup hits — not duplicated objects or double-counted stats."""
    from repro.checkpoint import AsyncWriter
    store = ChunkStore(tmp_path)
    w = AsyncWriter(num_threads=4)
    tree = {"w": np.random.RandomState(0)
            .standard_normal((128, 128)).astype(np.float32)}
    pends = [w.submit(store.write, i, f"u{i}", "weights", tree)
             for i in range(16)]
    w.drain()
    w.close()
    refs = [p.result() for p in pends]
    assert len({r.digest for r in refs}) == 1
    assert len(list((tmp_path / "objects").glob("*/*.chunk"))) == 1
    assert store.stats["full_chunks"] == 1
    assert store.stats["dedup_hits"] == 15


# ------------------------------------------------------------ manager-level
@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("llama3.2-3b", reduced=True)
    model = build_model(cfg)
    state = steps_lib.init_state(model, jax.random.key(0))
    registry = LayerRegistry(model)
    return model, state, registry


def test_resave_unchanged_state_writes_nothing(tmp_path, small_setup):
    """ISSUE acceptance: second FullPolicy save of the same state is ~0
    new bytes — every chunk dedups against the first event."""
    model, state, registry = small_setup
    mgr = CheckpointManager(tmp_path, registry,
                            make_policy("full", model.layer_units()),
                            async_save=False)
    mgr.save(state, step=10)
    first_written = mgr.last_save_stats["written_bytes"]
    assert first_written > 0
    usage1 = mgr.disk_usage()

    m2 = mgr.save(state, step=20)
    s = mgr.last_save_stats
    assert s["written_bytes"] == 0
    assert s["full_chunks"] == 0 and s["delta_chunks"] == 0
    assert s["dedup_hits"] == 2 * len(registry.unit_names())  # w + opt each
    assert mgr.disk_usage()["total"] == usage1["total"]
    # both manifests reference the same objects -> refcount 2
    d = m2.entries["block_000"]["weights"].digest
    assert mgr.store.refcount(d) == 2
    # restore from the deduped manifest is still bitwise exact
    restored = mgr.restore(steps_lib.state_specs(model))
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


def _sparse_drift(registry, state, unit):
    """Change a handful of elements in one block (delta-favourable)."""
    w = registry.extract_unit(state["params"], unit)
    leaves, treedef = jax.tree.flatten(w)
    a = np.asarray(leaves[0]).copy()
    a.reshape(-1)[:8] += np.asarray(1.0, a.dtype)
    leaves[0] = a
    return dict(state, params=registry.insert_unit(
        state["params"], unit, jax.tree.unflatten(treedef, leaves)))


def test_delta_manifest_restore_equals_full_restore(tmp_path, small_setup):
    """ISSUE acceptance: restore from a delta-encoded manifest is
    byte-identical to restore from a store with deltas disabled."""
    model, state, registry = small_setup
    state2 = _sparse_drift(registry, state, "block_001")

    restored = {}
    for name, delta in (("delta", True), ("plain", False)):
        mgr = CheckpointManager(tmp_path / name, registry,
                                make_policy("full", model.layer_units()),
                                async_save=False, delta=delta)
        mgr.save(state, step=10)
        m = mgr.save(state2, step=20)
        ref = m.entries["block_001"]["weights"]
        assert ref.stored == ("delta" if delta else "full")
        restored[name] = mgr.restore(steps_lib.state_specs(model))
        mgr.close()

    for a, b in zip(jax.tree.leaves(restored["delta"]),
                    jax.tree.leaves(restored["plain"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and both equal the source state bitwise
    for a, b in zip(jax.tree.leaves(restored["delta"]["params"]),
                    jax.tree.leaves(state2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_gc_drops_only_unshared_objects(tmp_path, small_setup):
    model, state, registry = small_setup
    mgr = CheckpointManager(tmp_path, registry,
                            make_policy("full", model.layer_units()),
                            async_save=False, keep=2)
    st = state
    for step in (10, 20, 30):
        st = _sparse_drift(registry, st, "block_000")
        mgr.save(st, step=step)
    assert mgr.manifests.all_steps() == [20, 30]
    # opt chunks never changed: shared across all events, still present
    opt_digest = mgr.manifests.load(30).entries["block_000"]["opt"].digest
    assert mgr.store.refcount(opt_digest) == 2
    # every object on disk is referenced by a retained manifest
    referenced = set()
    for s in (20, 30):
        referenced |= set(mgr.manifests.load(s).referenced_digests())
    assert set(mgr.store.iter_digests()) == referenced
    mgr.close()


def test_resave_same_step_does_not_leak_refcounts(tmp_path, small_setup):
    """Overwriting a step's manifest releases the replaced references."""
    model, state, registry = small_setup
    mgr = CheckpointManager(tmp_path, registry,
                            make_policy("full", model.layer_units()),
                            async_save=False)
    m = mgr.save(state, step=10)
    d = m.entries["block_000"]["weights"].digest
    assert mgr.store.refcount(d) == 1
    mgr.save(state, step=10)  # same step, same content: manifest replaced
    assert mgr.store.refcount(d) == 1  # not 2 — the old manifest is gone
    # replacing with drifted content: the old object keeps exactly the
    # references the new manifest still holds (delta base or nothing)
    state2 = _sparse_drift(registry, state, "block_000")
    m3 = mgr.save(state2, step=10)
    new_ref = m3.entries["block_000"]["weights"]
    assert new_ref.digest != d
    expected = 1 if new_ref.delta_base == d else 0
    assert mgr.store.refcount(d) == expected
    mgr.close()


def test_delta_run_survives_reopen(tmp_path, small_setup):
    """The rebase_every bound replays from the manifest chain: a restart
    must not reset the consecutive-delta counter (else one full base could
    underpin the whole retention window across crash loops)."""
    model, state, registry = small_setup
    def mk():
        return CheckpointManager(tmp_path, registry,
                                 make_policy("full", model.layer_units()),
                                 async_save=False, keep=16)
    mgr = mk()
    st = state
    mgr.save(st, step=0)
    for step in (1, 2):
        st = _sparse_drift(registry, st, "block_001")
        m = mgr.save(st, step=step)
        assert m.entries["block_001"]["weights"].stored == "delta"
    mgr.close()

    mgr2 = mk()  # "restart": counter must resume at 2, not 0
    for step in (3, 4):
        st = _sparse_drift(registry, st, "block_001")
        m = mgr2.save(st, step=step)
        assert m.entries["block_001"]["weights"].stored == "delta"
    st = _sparse_drift(registry, st, "block_001")
    m = mgr2.save(st, step=5)  # 5th consecutive delta candidate -> rebase
    assert m.entries["block_001"]["weights"].stored == "full"
    mgr2.close()


def test_refcounts_rebuild_across_reopen(tmp_path, small_setup):
    """A fresh manager derives refcounts from manifests (nothing persisted
    beyond the manifests themselves)."""
    model, state, registry = small_setup
    mgr = CheckpointManager(tmp_path, registry,
                            make_policy("full", model.layer_units()),
                            async_save=False)
    m1 = mgr.save(state, step=10)
    mgr.save(state, step=20)
    mgr.close()

    mgr2 = CheckpointManager(tmp_path, registry,
                             make_policy("full", model.layer_units()),
                             async_save=False, keep=1)
    d = m1.entries["block_000"]["weights"].digest
    assert mgr2.store.refcount(d) == 2
    restored = mgr2.restore(steps_lib.state_specs(model))
    assert int(restored["step"]) == 20
    mgr2.close()


def test_merge_shares_objects_across_sources(tmp_path, small_setup):
    """Digest-level merge copy: units with identical content (within or
    across sources) land as ONE object in the output store."""
    model, state, registry = small_setup
    # make block_001 and block_003 byte-identical: their chunks share a
    # digest, so the merge must copy the object exactly once
    state = dict(state, params=registry.insert_unit(
        state["params"], "block_003",
        registry.extract_unit(state["params"], "block_001")))
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path / "ck", registry, pol, async_save=False)
    mgr.save(state, step=100)
    state2 = _sparse_drift(registry, state, "block_000")
    mgr.save(state2, step=200)

    recipe = Recipe(
        base=CheckpointRef(tmp_path / "ck", 200),
        output=tmp_path / "merged",
        select=[SelectRule(units=["block_001", "embed"],
                           source=CheckpointRef(tmp_path / "ck", 100))])
    stats = merge(recipe, workers=2)
    # block_001@100 and block_003@200 carry the same digest
    assert stats["shared_chunks"] > 0

    out_m = ManifestStore(tmp_path / "merged").load(200)
    assert out_m.entries["block_001"]["weights"].digest == \
        out_m.entries["block_003"]["weights"].digest
    out_files = {f.stem
                 for f in (tmp_path / "merged" / "objects").glob("*/*.chunk")}
    assert out_m.entries["block_001"]["weights"].digest in out_files
    src_m = mgr.manifests.load(200)
    assert out_m.entries["block_001"]["weights"].digest == \
        src_m.entries["block_001"]["weights"].digest
    # merged output restores bitwise to the mixed state
    mgr2 = CheckpointManager(tmp_path / "merged", registry, pol,
                             async_save=False)
    got = mgr2.restore(steps_lib.state_specs(model))
    exp = registry.extract_unit(state2["params"], "block_000")
    for a, b in zip(jax.tree.leaves(exp),
                    jax.tree.leaves(registry.extract_unit(got["params"],
                                                          "block_000"))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()
    mgr2.close()


def test_merge_copies_delta_base_transitively(tmp_path, small_setup):
    """A delta-encoded unit merges correctly: its full base object rides
    along and the output restores byte-exactly."""
    model, state, registry = small_setup
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path / "ck", registry, pol, async_save=False)
    mgr.save(state, step=100)
    state2 = _sparse_drift(registry, state, "block_002")
    m2 = mgr.save(state2, step=200)
    ref = m2.entries["block_002"]["weights"]
    assert ref.stored == "delta"

    recipe = Recipe(base=CheckpointRef(tmp_path / "ck", 200),
                    output=tmp_path / "merged", select=[])
    merge(recipe, workers=2)
    out_store = ChunkStore(tmp_path / "merged")
    assert out_store.has(ref.digest) and out_store.has(ref.delta_base)
    tree, _ = out_store.read_digest(ref.digest)
    exp = registry.extract_unit(state2["params"], "block_002")
    for a, b in zip(jax.tree.leaves(exp), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()
