"""Process-backed IO lanes: conformance, conformity under faults, stress.

The tentpole invariant: ``io_backend="process"`` (subprocess workers +
shared-memory payloads) is byte-for-byte indistinguishable from the
thread backend — identical manifests, identical object digests on disk,
bit-exact restored tensors — including across a process restart and
under injected crashes/worker deaths.  Plus the worker-hygiene
invariants (workers never import jax; /dev/shm segments never leak) and
the lane-accounting regression (draining one lane while another is
flooded).
"""
import glob
import os
import signal
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import faults, workers
from repro.checkpoint.async_io import (
    AsyncWriteError,
    ProcessWorkerPool,
    TransferPool,
)
from repro.checkpoint.faults import InjectedCrash
from repro.checkpoint.saver import CheckpointManager
from repro.checkpoint.serial import ChunkCorruption
from repro.configs import get_config
from repro.core import LayerRegistry, make_policy
from repro.kernels.block_fp import ref as fp_ref
from repro.launch import steps as steps_lib
from repro.models import build_model

ARCH = "llama3.2-3b"


def _own_shm():
    """Shared-memory segments created by THIS process's arenas."""
    return sorted(glob.glob(f"/dev/shm/repro-io-{os.getpid():x}-*"))


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.disarm()
    yield
    faults.disarm()


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH, reduced=True)
    model = build_model(cfg)
    state1 = steps_lib.init_state(model, jax.random.key(0))

    def poke(x):
        x = np.array(x)
        x.flat[:1] += 1
        return x

    # Every leaf drifts, so event 2 really exercises gather/encode/write
    # on every (unit, kind) — no dedup early-outs.
    state2 = {"step": np.array(state1["step"]),
              "params": jax.tree.map(poke, state1["params"]),
              "opt": jax.tree.map(poke, state1["opt"])}
    return model, LayerRegistry(model), state1, state2


def _assert_states_equal(a, b, parts=("params", "opt")):
    for part in parts:
        for x, y in zip(jax.tree.leaves(a[part]), jax.tree.leaves(b[part])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _manifest_sig(mgr, step):
    """(digest, stored, delta_base) of every (unit, kind) at ``step``."""
    m = mgr.manifests.load(step)
    assert m is not None
    return {(unit, kind): (e.digest, e.stored, e.delta_base)
            for unit, kinds in m.entries.items()
            for kind, e in kinds.items()}


# ------------------------------------------------------ conformance matrix
@pytest.mark.process_io
@pytest.mark.parametrize("store", ["local", "tiered"])
def test_conformance_matrix_bit_exact(setup, tmp_path, store):
    """worker_backend x store_backend: two identical save sequences, one
    per worker backend, must produce identical manifests (digest, stored
    form, delta base per entry), identical object sets on disk, and —
    after a manager restart — bit-exact restored tensors."""
    model, registry, state1, state2 = setup
    like = steps_lib.state_specs(model)
    runs = {}
    for backend in ("thread", "process"):
        root = tmp_path / backend
        pol = make_policy("full", model.layer_units())
        mgr = CheckpointManager(root, registry, pol, fp_block_bytes=4096,
                                store_backend=store, io_backend=backend,
                                io_workers=2)
        mgr.save(state1, step=10)
        mgr.save(state2, step=20)
        assert mgr.last_save_stats["io_backend"] == backend
        if backend == "process":
            w = mgr.last_save_stats["workers"]
            assert w["worker_restarts"] == 0
            assert sum(l["tasks"] for l in w["lanes"].values()) > 0
        sigs = {s: _manifest_sig(mgr, s) for s in (10, 20)}
        digests = sorted(mgr.store.iter_digests())
        mgr.close()

        # Restart: a fresh manager on the same root (fresh worker fleet
        # under the process backend) restores the committed truth.
        mgr2 = CheckpointManager(root, registry, pol, async_save=False,
                                 store_backend=store, io_backend=backend,
                                 io_workers=2)
        got = mgr2.restore(like, step=20)
        rstats = dict(mgr2.last_restore_stats)
        assert rstats["io_backend"] == backend
        assert not rstats["fallback_units"]
        _assert_states_equal(state2, got)
        leaves = [np.asarray(x).tobytes() for part in ("params", "opt")
                  for x in jax.tree.leaves(got[part])]
        mgr2.close()
        runs[backend] = (sigs, digests, leaves, rstats)

    tsig, tdig, tleaves, _ = runs["thread"]
    psig, pdig, pleaves, prs = runs["process"]
    assert tsig == psig, "manifests differ between worker backends"
    assert tdig == pdig, "object digest sets differ between worker backends"
    assert tleaves == pleaves, "restored bytes differ between worker backends"
    # The process restore actually offloaded work to subprocess workers.
    assert prs["workers"]["tasks"] > 0
    assert prs["workers"]["worker_restarts"] == 0
    assert not _own_shm()


@pytest.mark.process_io
def test_gc_parity_thread_vs_process(setup, tmp_path):
    """Retention GC sweeps the same objects under either worker backend:
    after the oldest manifest drops out, the surviving digest sets are
    identical and the latest event still restores bit-exact."""
    model, registry, state1, state2 = setup
    like = steps_lib.state_specs(model)
    survivors = {}
    for backend in ("thread", "process"):
        pol = make_policy("full", model.layer_units())
        mgr = CheckpointManager(tmp_path / backend, registry, pol,
                                fp_block_bytes=4096, keep=1,
                                io_backend=backend, io_workers=2)
        mgr.save(state1, step=10)
        mgr.save(state2, step=20)  # keep=1: step 10 is GC'd here
        assert mgr.manifests.all_steps() == [20]
        survivors[backend] = sorted(mgr.store.iter_digests())
        got = mgr.restore(like, step=20)
        _assert_states_equal(state2, got)
        mgr.close()
    assert survivors["thread"] == survivors["process"]


# ------------------------------------------------- crash-matrix sample
@pytest.mark.process_io
@pytest.mark.parametrize("point", ["gather", "object_write",
                                   "manifest_commit"])
def test_crash_matrix_sample_process_backend(setup, tmp_path, point):
    """A sample of the resiliency crash matrix re-run under the process
    backend: die mid-save of event 2, previous manifest stays
    authoritative and restores bit-exact with zero fallbacks."""
    model, registry, state1, state2 = setup
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path, registry, pol, fp_block_bytes=4096,
                            io_backend="process", io_workers=2)
    mgr.save(state1, step=10)
    with faults.scoped(point):
        with pytest.raises((InjectedCrash, AsyncWriteError)):
            mgr.save(state2, step=20)
    assert not faults.pending()
    try:
        mgr.close()
    except (AsyncWriteError, InjectedCrash):
        pass

    mgr2 = CheckpointManager(tmp_path, registry, pol, async_save=False,
                             io_backend="process", io_workers=2)
    assert mgr2.manifests.latest_step() == 10
    got = mgr2.restore(steps_lib.state_specs(model))
    assert int(np.asarray(got["step"])) == 10
    assert not mgr2.last_restore_stats["fallback_units"]
    _assert_states_equal(state1, got)
    mgr2.close()
    assert not _own_shm()


# --------------------------------------------------------- worker hygiene
@pytest.mark.process_io
def test_worker_processes_never_import_jax():
    pool = ProcessWorkerPool(1)
    try:
        info = pool.call("ping")
        assert info["pid"] != os.getpid()
        assert info["jax"] is False
        mods = pool.call("modules")
        assert not any(m == "jax" or m.startswith(("jax.", "repro."))
                       for m in mods), "worker imported jax or repro"
    finally:
        pool.close()


def test_fingerprint_pairs_matches_kernel_ref():
    """workers.fingerprint_pairs intentionally duplicates the block_fp
    reference (delegating either way would taint the worker with jax or
    create an import cycle) — pin them bit-identical."""
    rs = np.random.RandomState(0)
    for n in (0, 1, 5, 4095, 4096, 4097, 65536, 200001):
        raw = rs.bytes(n)
        np.testing.assert_array_equal(
            workers.fingerprint_pairs(raw, 4096),
            fp_ref.fingerprint_bytes(raw, 4096))


@pytest.mark.process_io
def test_worker_errors_map_to_parent_exceptions(tmp_path):
    """IoDispatch maps worker error kinds back onto the exact exception
    types the inline (thread) path raises — callers can't tell the
    backends apart by except clause."""
    tp = TransferPool(2, worker_backend="process", io_workers=1,
                      shm_min_bytes=1024)
    try:
        d = tp.dispatch
        with pytest.raises(ChunkCorruption):
            d.call("decode_chunk_items", b"definitely not msgpack", True)
        with pytest.raises(FileNotFoundError):
            d.call("file_read", str(tmp_path / "missing" / "nope.chunk"))
        with pytest.raises(AsyncWriteError, match="worker task failed"):
            d.call("boom", "kaput")
        # The pool survives mapped errors — no restarts, still serving.
        assert tp.workers.stats()["worker_restarts"] == 0
        assert d.call("echo", 7) == 7
    finally:
        tp.close()


@pytest.mark.process_io
def test_worker_file_io_roundtrip_via_shm(tmp_path):
    pool = ProcessWorkerPool(1, shm_min_bytes=1024)
    try:
        data = os.urandom(200_000)
        path = str(tmp_path / "ab" / "obj.chunk")
        assert pool.call("file_write_atomic", path, data, False,
                         "deadbeef-1") == len(data)
        assert pool.call("file_read", path) == data
        # No tmp debris: the worker's atomic_write renamed into place.
        assert os.listdir(tmp_path / "ab") == ["obj.chunk"]
        st = pool.stats()
        assert st["lanes"]["io"]["bytes_shm"] >= len(data)
    finally:
        pool.close()
    assert not _own_shm()


@pytest.mark.process_io
def test_pool_start_sweeps_dead_owner_shm_debris(tmp_path):
    """A SIGKILLed process can never unlink its own arena/scratch files
    — the next pool start must reclaim debris whose embedded creator
    pid is dead, and must leave a live pid's files alone."""
    import subprocess, sys
    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    proc.wait()  # dead, reaped pid — guaranteed not alive
    dead = f"/dev/shm/repro-io-{proc.pid:x}-feed00-s1"
    live = f"/dev/shm/repro-io-{os.getpid():x}-feed00-s1"
    with open(dead, "wb") as f:
        f.write(b"x")
    with open(live, "wb") as f:
        f.write(b"x")
    try:
        pool = ProcessWorkerPool(1)
        pool.close()
        assert not os.path.exists(dead)
        assert os.path.exists(live)  # own pid: never swept by others
    finally:
        for p in (dead, live):
            try:
                os.unlink(p)
            except OSError:
                pass


# ---------------------------------------- lane accounting (regression)
def test_drain_lane_isolated_from_flooded_lane():
    """Regression for the outstanding()/drain() lane-accounting race:
    draining one lane must neither wait on nor steal errors from a lane
    that is flooded with slow/failing work."""
    tp = TransferPool(4)
    gate = threading.Event()
    try:
        blockers = [tp.submit("slow", gate.wait, 30) for _ in range(3)]
        p = tp.submit("fast", lambda: 42)
        t0 = time.time()
        tp.drain("fast")  # must not wait for the flooded lane
        assert time.time() - t0 < 5.0
        assert p.result() == 42
        assert tp.outstanding("fast") == 0
        assert tp.outstanding("slow") == 3

        tp.submit("slow", lambda: 1 / 0)
        gate.set()
        tp.drain("fast")  # still clean: slow's error must not leak here
        with pytest.raises(AsyncWriteError, match="lane 'slow'"):
            tp.drain("slow")
        assert tp.outstanding("slow") == 0
        for b in blockers:
            assert b.result() is not None or b.done()
    finally:
        gate.set()
        tp.close()


# ----------------------------------------------------------- stress tier
@pytest.mark.process_io
def test_stress_interleaved_submit_drain():
    """Hundreds of interleaved submit/drain calls from multiple threads
    across shared lanes must complete inside a bounded wall-clock (no
    deadlock) with exact task accounting and no shm leaks."""
    tp = TransferPool(4, worker_backend="process", io_workers=2,
                      shm_min_bytes=1024)
    errors = []
    per_thread, n_threads = 60, 6

    def hammer(idx):
        rs = np.random.RandomState(idx)
        lane = f"lane{idx % 3}"
        for i in range(per_thread):
            payload = rs.bytes(int(rs.randint(16, 5000)))
            tp.submit_task(lane, "blake2_hex", payload)
            if i % 7 == idx % 7:
                try:
                    tp.drain(lane)
                except AsyncWriteError as e:  # pragma: no cover
                    errors.append(e)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(n_threads)]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert all(not t.is_alive() for t in threads), "stress run deadlocked"
    tp.drain_all()
    assert not errors
    st = tp.stats()
    assert sum(l["tasks"] for l in st["lanes"].values()) \
        == per_thread * n_threads
    assert st["worker_restarts"] == 0
    tp.close()
    assert time.time() - t0 < 120
    assert not _own_shm()


@pytest.mark.process_io
def test_stress_close_races_submitters():
    """close() racing live submitters: accepted work drains, late
    submitters get a loud AsyncWriteError, nothing hangs, no shm leaks."""
    for _ in range(3):
        tp = TransferPool(3, worker_backend="process", io_workers=2,
                          shm_min_bytes=1024)

        def submitter():
            while True:
                try:
                    tp.submit_task("w", "echo", b"x" * 2048)
                except AsyncWriteError:
                    return  # pool closed underneath us — expected

        threads = [threading.Thread(target=submitter) for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.15)
        tp.close()
        for t in threads:
            t.join(timeout=60)
        assert all(not t.is_alive() for t in threads), "submitter hung"
        assert not _own_shm()


@pytest.mark.process_io
def test_worker_sigkill_mid_task_fails_loudly_and_respawns():
    """SIGKILL a worker mid-task: the in-flight call fails with
    AsyncWriteError (never hangs), the pool respawns a replacement, and
    later calls succeed."""
    pool = ProcessWorkerPool(1, shm_min_bytes=1024)
    try:
        pid0 = pool.worker_pids()[0]
        res = {}

        def victim():
            try:
                pool.call("sleep", 30.0)
            except BaseException as e:  # noqa: BLE001
                res["exc"] = e

        th = threading.Thread(target=victim)
        th.start()
        time.sleep(0.3)  # let the request reach the worker
        os.kill(pid0, signal.SIGKILL)
        th.join(timeout=30)
        assert not th.is_alive(), "call hung on a SIGKILLed worker"
        assert isinstance(res.get("exc"), AsyncWriteError)
        assert str(pid0) in str(res["exc"])
        assert pool.stats()["worker_restarts"] == 1
        info = pool.call("ping")  # the replacement is live
        assert info["pid"] != pid0
    finally:
        pool.close()
    assert not _own_shm()


@pytest.mark.process_io
def test_worker_death_mid_sequence_prior_event_survives(setup, tmp_path):
    """Kill the whole worker fleet between events: the next save fails
    loudly (AsyncWriteError on the write lane's drain), the fleet
    respawns, the RETRY of the same step commits, and the previously
    completed event restores bit-exact throughout."""
    model, registry, state1, state2 = setup
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path, registry, pol, fp_block_bytes=4096,
                            io_backend="process", io_workers=2)
    mgr.save(state1, step=10)
    for pid in mgr.transfer_pool.workers.worker_pids():
        os.kill(pid, signal.SIGKILL)
    with pytest.raises(AsyncWriteError):
        mgr.save(state2, step=20)
    assert mgr.transfer_pool.workers.stats()["worker_restarts"] >= 1

    m = mgr.save(state2, step=20)  # retry on the respawned fleet
    assert m.step == 20
    like = steps_lib.state_specs(model)
    _assert_states_equal(state1, mgr.restore(like, step=10))
    _assert_states_equal(state2, mgr.restore(like, step=20))
    mgr.close()
    assert not _own_shm()
