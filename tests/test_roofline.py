"""Roofline machinery: HLO parsing (trip counts, dots, collectives) against
programs with known costs, and the cost_analysis facts the methodology
relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import account, analyze_compiled, hw
from repro.roofline.flops import count_active_params, model_flops
from repro.configs import SHAPES, get_config
from repro.models import build_model


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    n = 64
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    c = _compile(lambda a: a @ a, x)
    acc = account(c.as_text(), num_devices=1)
    # 2n^3 matmul + small elementwise slack
    assert abs(acc.flops - 2 * n ** 3) / (2 * n ** 3) < 0.05


def test_scan_trip_count_multiplies():
    n, layers = 32, 7
    w = jax.ShapeDtypeStruct((layers, n, n), jnp.float32)
    x0 = jax.ShapeDtypeStruct((n,), jnp.float32)

    def f(w, x):
        def body(h, wi):
            return wi @ h, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    c = _compile(f, w, x0)
    acc = account(c.as_text(), num_devices=1)
    expected = layers * 2 * n * n
    assert abs(acc.flops - expected) / expected < 0.2, acc.flops
    # raw cost_analysis counts the body once (the known undercount);
    # jax < 0.5 returns a per-computation list rather than a dict
    ca = c.cost_analysis()
    raw = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    assert raw < expected / 2


def test_nested_scan_trips_compose():
    n, inner, outer = 16, 3, 5
    w = jax.ShapeDtypeStruct((outer, inner, n, n), jnp.float32)
    x0 = jax.ShapeDtypeStruct((n,), jnp.float32)

    def f(w, x):
        def outer_body(h, wo):
            def inner_body(hh, wi):
                return wi @ hh, None
            h2, _ = jax.lax.scan(inner_body, h, wo)
            return h2, None
        h, _ = jax.lax.scan(outer_body, x, w)
        return h

    c = _compile(f, w, x0)
    acc = account(c.as_text(), num_devices=1)
    expected = outer * inner * 2 * n * n
    assert acc.dot_count == outer * inner
    assert abs(acc.dot_flops - expected) / expected < 1e-6, acc.dot_flops


def test_collective_parse_smoke():
    text = """
ENTRY %main_spmd (p: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8]{1,0} parameter(0)
  %ag = f32[4,32]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  ROOT %ar = f32[4,8]{1,0} all-reduce(%x), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%sum
}
"""
    acc = account(text, num_devices=8)
    ag = acc.collectives["all-gather"]
    ar = acc.collectives["all-reduce"]
    assert ag["count"] == 1 and ar["count"] == 1
    assert ag["bytes"] == 4 * 32 * 4
    np.testing.assert_allclose(ag["wire_bytes"], 4 * 32 * 4 * 3 / 4)
    np.testing.assert_allclose(ar["wire_bytes"], 2 * 4 * 8 * 4 * 7 / 8)


def test_active_params_moe_discount():
    model = build_model(get_config("deepseek-v2-lite-16b"))
    total, active = count_active_params(model)
    assert active < 0.45 * total  # 64 experts, top-6 + shared
    dense = build_model(get_config("yi-9b"))
    t2, a2 = count_active_params(dense)
    assert a2 > 0.9 * t2


def test_model_flops_conventions():
    model = build_model(get_config("yi-9b"))
    tr = model_flops(model, SHAPES["train_4k"])
    pf = model_flops(model, SHAPES["prefill_32k"])
    de = model_flops(model, SHAPES["decode_32k"])
    # train = 3x prefill per token; decode = prefill per token
    tokens_tr = 4096 * 256
    tokens_pf = 32768 * 32
    assert abs(tr / tokens_tr - 3 * pf / tokens_pf) / (tr / tokens_tr) < 1e-6
    assert abs(de / 128 - pf / tokens_pf) < 1e-3 * pf / tokens_pf


def test_report_terms_and_dominance():
    r = analyze_compiled(
        arch="x", shape="train_4k", mesh_name="16x16", chips=256,
        hlo_text="ENTRY %m (p: f32[2]) -> f32[2] {\n ROOT %t = f32[2]{0} tanh(%p)\n}",
        model_flops=1e12,
        hbm_model={"total": hw.HBM_BW},  # 1 second of HBM traffic
    )
    assert abs(r.memory_s - 1.0) < 1e-9
    assert r.dominant == "memory"
    assert r.step_time_s == r.memory_s
