"""The fault-tolerant remote tier: retry policy, circuit breaker,
hedged GETs, the simulated object service's multipart/ranged protocol,
and the three-tier remote3 composition (degraded commits, healing)."""
import time

import numpy as np
import pytest

from repro.checkpoint import (
    ChunkStore,
    CircuitBreaker,
    RemoteBackend,
    RemoteUnavailable,
    RetryPolicy,
    SimulatedObjectService,
)
from repro.checkpoint.backends.retry import LatencyTracker


def _svc(tmp_path, **kw):
    return SimulatedObjectService(tmp_path / "remote", **kw)


def _fast_policy(**kw):
    kw.setdefault("attempts", 3)
    kw.setdefault("base_delay", 0.001)
    kw.setdefault("max_delay", 0.002)
    return RetryPolicy(**kw)


# ------------------------------------------------------------ retry policy
def test_retry_policy_bounded_and_deterministic():
    pol = _fast_policy(attempts=4)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    retries = []
    out = pol.run(flaky, key="k",
                  on_retry=lambda a, e: retries.append(a), sleep=lambda s: None)
    assert out == "ok" and len(calls) == 3 and len(retries) == 2
    # jitter is a pure function of (seed, key, attempt)
    assert pol.delay("k", 1) == pol.delay("k", 1)
    assert pol.delay("k", 1) != pol.delay("other", 1)
    # exhausted attempts re-raise the final error
    with pytest.raises(OSError):
        pol.run(lambda: (_ for _ in ()).throw(OSError("down")),
                key="k", sleep=lambda s: None)


def test_retry_policy_never_retries_not_found_or_corruption():
    pol = _fast_policy()
    calls = []

    def absent():
        calls.append(1)
        raise FileNotFoundError("no such key")

    with pytest.raises(FileNotFoundError):
        pol.run(absent, key="k", sleep=lambda s: None)
    assert len(calls) == 1, "absence is an answer, not a transient"


def test_circuit_breaker_opens_and_half_open_probe():
    t = [0.0]
    br = CircuitBreaker(failures=3, cooldown=1.0, clock=lambda: t[0])
    assert br.state == "closed" and br.allow()
    for _ in range(3):
        br.record_failure()
    assert br.state == "open" and br.opens == 1
    assert not br.allow(), "open circuit fails fast"
    t[0] = 1.5  # cooldown elapsed: probes may run again
    assert br.allow() and br.state == "half-open"
    br.record_failure()  # probe failed: back to open
    assert br.state == "open" and br.opens == 2
    t[0] = 3.0
    assert br.allow()
    br.record_success()  # probe succeeded: closed again
    assert br.state == "closed" and br.allow()


def test_latency_tracker_percentile_needs_min_samples():
    lt = LatencyTracker(min_samples=4)
    for v in (0.01, 0.02):
        lt.record(v)
    assert lt.percentile(95) is None
    for v in (0.01, 0.015):
        lt.record(v)
    p = lt.percentile(95)
    assert p is not None and 0.01 <= p <= 0.02


# ------------------------------------------------- simulated object service
def test_service_multipart_put_ranged_get(tmp_path):
    svc = _svc(tmp_path)
    be = RemoteBackend(svc, policy=_fast_policy(), part_size=8,
                       range_bytes=8, hedge=False)
    data = bytes(range(20))
    be.write("aabbcc", data)
    assert svc.ops["put_part"] == 3  # ceil(20/8) parts
    assert be.read("aabbcc") == data
    assert svc.ops["get"] == 3  # ranged reads
    assert be.size("aabbcc") == 20
    # zero-byte object publishes and reads back
    be.write("dd0000", b"")
    assert be.read("dd0000") == b""
    with pytest.raises(FileNotFoundError):
        be.read("ee0000")
    assert be.delete("aabbcc") == 20
    assert not be.has("aabbcc")


def test_service_abandoned_upload_never_torn_and_swept(tmp_path, monkeypatch):
    svc = _svc(tmp_path)
    be = RemoteBackend(svc, policy=_fast_policy(attempts=1), part_size=4,
                       hedge=False)
    # die after the first part: no object may be visible
    real = svc.put_part
    calls = []

    def dying(upload, index, data, **kw):
        calls.append(index)
        if index == 1:
            raise OSError("writer died")
        return real(upload, index, data, **kw)

    monkeypatch.setattr(svc, "put_part", dying)
    with pytest.raises(OSError):
        be.write("aa1111", b"0123456789")
    assert not be.has("aa1111"), "partial upload must never publish"
    monkeypatch.setattr(svc, "put_part", real)
    # another process's stage is reclaimable garbage
    stage = svc.root / "uploads" / "aa1111.fffff-1-1"
    stage.mkdir(parents=True)
    (stage / "part-000000").write_bytes(b"zzzz")
    assert svc.sweep_uploads() == 4
    assert not stage.exists()


def test_remote_retries_absorb_seeded_faults_clean_path_free(tmp_path):
    svc = _svc(tmp_path, error_rate=0.3, seed=11)
    be = RemoteBackend(svc, policy=_fast_policy(attempts=6),
                       breaker=CircuitBreaker(failures=50), hedge=False)
    data = b"x" * 64
    for i in range(4):
        be.write(f"aa{i:04d}", data)
        assert be.read(f"aa{i:04d}") == data
    flaky = be.tier_stats()["remote_retries"]
    assert flaky > 0, "error_rate=0.3 must force retries"
    svc.error_rate = 0.0
    before = be.tier_stats()["remote_retries"]
    be.write("bb0000", data)
    assert be.read("bb0000") == data
    assert be.tier_stats()["remote_retries"] == before, \
        "clean path must not retry"


def test_remote_breaker_opens_on_outage_then_fast_fails(tmp_path):
    svc = _svc(tmp_path)
    be = RemoteBackend(svc, policy=_fast_policy(attempts=2),
                       breaker=CircuitBreaker(failures=2, cooldown=60.0),
                       hedge=False)
    be.write("aa0001", b"payload")
    svc.set_outage(True)
    with pytest.raises(OSError):
        be.read("aa0001")
    assert be.tier_stats()["remote_breaker_state"] == "open"
    with pytest.raises(RemoteUnavailable):
        be.read("aa0001")
    stats = be.tier_stats()
    assert stats["remote_fast_fails"] >= 1
    assert stats["remote_breaker_opens"] == 1
    # soft-failing probes degrade instead of raising
    assert be.has("aa0001") is False
    assert be.delete("aa0001") == 0
    assert list(be.keys()) == []
    assert stats["remote_soft_fails"] < be.tier_stats()["remote_soft_fails"]


def test_remote_outage_marker_is_cross_instance(tmp_path):
    """The OUTAGE marker lives in the bucket directory, so a supervisor
    process can fail a child's remote without sharing state."""
    svc1 = _svc(tmp_path)
    svc2 = SimulatedObjectService(tmp_path / "remote")
    svc1.set_outage(True)
    be2 = RemoteBackend(svc2, policy=_fast_policy(attempts=1), hedge=False)
    with pytest.raises(OSError):
        be2.read("aa0001")
    svc1.heal()
    be2.write("aa0001", b"ok")
    assert be2.read("aa0001") == b"ok"


def test_remote_hedged_get_races_slow_primary(tmp_path):
    svc = _svc(tmp_path, latency=0.001)
    be = RemoteBackend(svc, policy=_fast_policy(),
                       hedge=True, hedge_min_delay=0.02)
    be.write("aa0001", b"payload")
    for _ in range(6):  # warm the latency tracker past min_samples
        assert be.read("aa0001") == b"payload"
    assert be.tier_stats()["remote_hedges"] == 0, \
        "fast reads must not hedge"
    # one giant latency spike on the next get op: the primary stalls
    # past hedge_after and the hedged second GET wins the race
    n_next_get = svc._op_n + 1
    svc.spike_ops = {n_next_get}
    svc.spike_latency = 1.0
    t0 = time.monotonic()
    assert be.read("aa0001") == b"payload"
    elapsed = time.monotonic() - t0
    stats = be.tier_stats()
    assert stats["remote_hedges"] == 1
    assert stats["remote_hedge_wins"] == 1
    assert elapsed < 0.9, "hedged GET should beat the 1s spike"
    be.close()


def test_remote_per_op_timeout_is_transient(tmp_path):
    svc = _svc(tmp_path, latency=0.05)
    be = RemoteBackend(svc, policy=_fast_policy(attempts=2, timeout=0.005),
                       hedge=False)
    with pytest.raises(OSError):
        be.write("aa0001", b"payload")  # every op exceeds the budget
    assert be.tier_stats()["remote_retries"] >= 1


# ------------------------------------------------------ remote3 composition
def test_remote3_three_tier_labels_and_durability(tmp_path):
    store = ChunkStore(tmp_path, backend="remote3",
                       remote_opts={"latency": 0.0, "seed": 1})
    tree = {"w": np.arange(64, dtype=np.float32)}
    ref = store.write(1, "u", "weights", tree)
    tb = store.backend.tier_backends()
    assert list(tb) == ["hot", "durable", "remote"]
    store.drain_spill()
    d = store.durability()
    assert d["durable_on"] == "remote" and not d["degraded"]
    assert d["tiers"] == {"hot": 0, "durable": 0}
    # every tier holds the object
    assert tb["hot"].has(ref.digest)
    assert tb["durable"].has(ref.digest)
    assert tb["remote"].has(ref.digest)
    assert store.locate(ref.digest) == "hot"
    store.close()


def test_remote3_outage_degrades_then_heals(tmp_path):
    store = ChunkStore(tmp_path, backend="remote3",
                       remote_opts={"latency": 0.0, "seed": 1,
                                    "attempts": 2, "base_delay": 0.001,
                                    "failures": 2, "cooldown": 0.02})
    svc = store.backend.tier_backends()["remote"].service
    svc.set_outage(True)
    tree = {"w": np.arange(32, dtype=np.float32)}
    ref = store.write(1, "u", "weights", tree)
    store.drain_spill()  # must NOT raise: remote tier is best-effort
    d = store.durability()
    assert d["durable_on"] == "durable" and d["degraded"]
    assert d["pending_spill"] == 1
    assert store.backend.tier_backends()["durable"].has(ref.digest)
    svc.heal()
    time.sleep(0.03)  # past breaker cooldown
    store.drain_spill()
    d = store.durability()
    assert d["durable_on"] == "remote" and not d["degraded"]
    assert store.backend.tier_backends()["remote"].has(ref.digest)
    store.close()


def test_remote3_restart_reads_from_remote_and_rewarns_disk(tmp_path):
    """A lost disk blob re-warms from the remote tier on read
    (promotion-on-read on the inner boundary)."""
    store = ChunkStore(tmp_path, backend="remote3",
                       remote_opts={"latency": 0.0, "seed": 1})
    tree = {"w": np.arange(48, dtype=np.float32)}
    ref = store.write(1, "u", "weights", tree)
    store.drain_spill()
    store.close()
    # restart with the disk tree gone: only the bucket survives
    disk = tmp_path / "objects"
    for p in disk.glob("*/*.chunk"):
        p.unlink()
    store2 = ChunkStore(tmp_path, backend="remote3",
                        remote_opts={"latency": 0.0, "seed": 1})
    out, _ = store2.read(ref)
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert store2.backend.tier_backends()["durable"].has(ref.digest), \
        "read must re-warm the disk tier from remote"
    store2.close()
