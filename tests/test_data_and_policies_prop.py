"""Property-style invariants: policy coverage, yamlish roundtrips, memory
model sanity, int8 end-to-end resume quality."""
import numpy as np
import pytest

from proptest import cases

from repro.configs import SHAPES, get_config
from repro.core import make_policy
from repro.core.policies import PolicyContext
from repro.core import yamlish
from repro.models import build_model


# ----------------------------------------------------- policy coverage
@pytest.mark.parametrize("policy,kw,horizon", [
    ("parity", {}, 2),
    ("interval", {"stride": 3}, 3),
    ("filtered", {"first_k": 1, "last_k": 1, "rest_every": 2}, 4),
])
def test_any_policy_covers_all_units_within_horizon(policy, kw, horizon):
    """Invariant: within `horizon` consecutive events every unit is saved at
    least once — the manifest chain can never reference unboundedly stale
    chunks."""
    model = build_model(get_config("yi-9b", reduced=True))
    pol = make_policy(policy, model.layer_units(), **kw)
    for start in range(5):
        union = set()
        for ev in range(start, start + horizon):
            union |= set(pol.select(PolicyContext(ev, ev * 100)))
        assert union == set(pol.all_units()), (policy, start)


def test_policy_selection_is_deterministic():
    model = build_model(get_config("llama3.2-3b", reduced=True))
    for name in ("full", "parity", "filtered", "interval"):
        pol = make_policy(name, model.layer_units())
        a = [pol.select(PolicyContext(e, e)) for e in range(6)]
        b = [pol.select(PolicyContext(e, e)) for e in range(6)]
        assert a == b


# ------------------------------------------------------------- yamlish
def _rand_value(rs, depth=0):
    kind = rs.randint(0, 6 if depth < 2 else 4)
    if kind == 0:
        return int(rs.randint(-100, 100))
    if kind == 1:
        return bool(rs.randint(2))
    if kind == 2:
        return None
    if kind == 3:
        return "v" + str(rs.randint(1000))
    if kind == 4:
        return {f"k{i}": _rand_value(rs, depth + 1)
                for i in range(rs.randint(1, 4))}
    return [_rand_value(rs, depth + 1) for _ in range(rs.randint(1, 4))]


def test_yamlish_roundtrip_property():
    for doc in cases(10, lambda rs: {f"k{i}": _rand_value(rs)
                                     for i in range(rs.randint(1, 5))}):
        out = yamlish.loads(yamlish.dumps(doc))
        assert out == doc, (doc, out)


# ------------------------------------------------------- memory model
def test_hbm_model_scales_sanely():
    from repro.roofline.memory_model import estimate_hbm_bytes
    m_small = build_model(get_config("llama3.2-3b"))
    m_big = build_model(get_config("yi-9b"))
    tr = SHAPES["train_4k"]
    a = estimate_hbm_bytes(m_small, tr)["total"]
    b = estimate_hbm_bytes(m_big, tr)["total"]
    assert b > a  # bigger model, more traffic
    de = estimate_hbm_bytes(m_small, SHAPES["decode_32k"])
    assert de["weights"] > 0 and de["kv_cache"] > 0
    # decode traffic per step far below train traffic per step
    assert de["total"] < a / 10


# --------------------------------------------- int8 checkpoint resume
def test_int8_checkpoint_resume_trains_on(tmp_path):
    """Beyond-paper compression composes with selectivity: resuming from a
    lossy int8 checkpoint still trains (loss within a band of the lossless
    resume; codec="auto" = best available lossless codec)."""
    from repro.launch.train import SimulatedFailure, train

    base = dict(arch="llama3.2-3b", total_steps=60, batch=4, seq_len=32,
                ckpt_interval=20, seed=7, lr=2e-3)
    try:
        train(ckpt_dir=str(tmp_path / "z"), policy_name="parity",
              codec="auto", fail_at=50, **base)
    except SimulatedFailure:
        pass
    r_z = train(ckpt_dir=str(tmp_path / "z"), policy_name="parity",
                codec="auto", resume=True, **base)
    try:
        train(ckpt_dir=str(tmp_path / "q"), policy_name="parity",
              codec="int8", fail_at=50, **base)
    except SimulatedFailure:
        pass
    r_q = train(ckpt_dir=str(tmp_path / "q"), policy_name="parity",
                codec="int8", resume=True, **base)
    assert abs(r_q["final_loss"] - r_z["final_loss"]) < 0.5
    # and the int8 checkpoint is materially smaller
    assert r_q["ckpt_bytes"] < 0.55 * r_z["ckpt_bytes"]
