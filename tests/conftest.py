import os
import sys
from pathlib import Path

# Tests must see the single real CPU device (the 512-device flag is set ONLY
# inside launch/dryrun.py); keep any inherited flag out of the environment.
os.environ.pop("XLA_FLAGS", None)

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long end-to-end trainer/subprocess tests (excluded from the "
        "smoke tier: scripts/check.sh smoke)")
    config.addinivalue_line(
        "markers",
        "process_io: subprocess IO-worker conformance/stress tests "
        "(spawn worker processes and shared-memory segments; see "
        "tests/test_io_workers.py)")


@pytest.fixture(scope="session", autouse=True)
def shm_clean_guard():
    """/dev/shm hygiene: every ``repro-io-*`` shared-memory segment this
    test process created — worker arena/scratch files (process-backed IO
    lanes), ``-stage-`` staging slots (overlapped saves), and ``-cache-``
    block-cache segments (shm-backed BlockCache, docs/serving.md) share
    the owner-pid prefix — must be unlinked by the time the session ends;
    a leak means some TransferPool, ProcessWorkerPool, StagingArena, or
    BlockCache was never closed."""
    import glob
    prefix = f"/dev/shm/repro-io-{os.getpid():x}-"
    yield
    leftovers = sorted(glob.glob(prefix + "*"))
    assert not leftovers, (
        f"leaked IO-worker shared-memory segments: {leftovers}")


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)
