import os
import sys
from pathlib import Path

# Tests must see the single real CPU device (the 512-device flag is set ONLY
# inside launch/dryrun.py); keep any inherited flag out of the environment.
os.environ.pop("XLA_FLAGS", None)

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long end-to-end trainer/subprocess tests (excluded from the "
        "smoke tier: scripts/check.sh smoke)")


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)
