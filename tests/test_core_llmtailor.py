"""LLMTailor core: 2L+x groups, policies, recipes, explicit merge engine,
delta tracker, yamlish."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    DeltaTracker,
    LayerRegistry,
    Recipe,
    make_policy,
    merge,
)
from repro.core.policies import PolicyContext
from repro.core.recipe import CheckpointRef, SelectRule
from repro.core import yamlish
from repro.checkpoint.saver import CheckpointManager
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.optim import build_group_spec, decay_mask


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-3b", reduced=True)
    model = build_model(cfg)
    state = steps_lib.init_state(model, jax.random.key(0))
    return model, state, LayerRegistry(model)


# --------------------------------------------------------------- 2L+x groups
def test_group_spec_is_2l_plus_x(setup):
    model, _, registry = setup
    cfg = model.cfg
    spec = registry.group_spec
    blocks = [u for u in registry.units if u.kind == "block"]
    aux = [u for u in registry.units if u.kind != "block"]
    # paper §4.1: 2 groups per transformer layer + 1 per aux layer
    assert spec.num_groups == 2 * len(blocks) + len(aux)
    # fixed ordering: no-decay block groups, aux, decay block groups (Fig. 3)
    kinds = [(g.unit.startswith("block"), g.decay) for g in spec.groups]
    nb = len(blocks)
    assert all(k == (True, False) for k in kinds[:nb])
    assert all(k == (True, True) for k in kinds[-nb:])


def test_decay_mask_excludes_norms_and_biases(setup):
    model, state, _ = setup
    mask = decay_mask(model)
    flat = dict(__import__("repro.checkpoint.serial", fromlist=["x"])
                .flatten_with_paths(mask))
    for path, v in flat.items():
        if any(t in path for t in ("ln", "norm", "scale", "A_log", "D_skip",
                                   "dt_bias")):
            assert v is False, path
    # weights decay
    assert any(v for v in flat.values())


def test_group_indices_deterministic(setup):
    model, _, _ = setup
    s1 = build_group_spec(model, weight_decay=0.1)
    s2 = build_group_spec(model, weight_decay=0.1)
    assert [(g.index, g.unit, g.decay) for g in s1.groups] == \
        [(g.index, g.unit, g.decay) for g in s2.groups]


# ------------------------------------------------------------------ policies
def _mk_policy(name, model, **kw):
    return make_policy(name, model.layer_units(), **kw)


def test_parity_covers_everything_in_two_events(setup):
    model, _, registry = setup
    pol = _mk_policy("parity", model)
    s0 = set(pol.select(PolicyContext(0, 0)))
    s1 = set(pol.select(PolicyContext(1, 0)))
    assert s0 | s1 == set(registry.unit_names())
    blocks0 = {u for u in s0 if u.startswith("block")}
    blocks1 = {u for u in s1 if u.startswith("block")}
    assert not (blocks0 & blocks1)
    assert "embed" in s1 and "embed" not in s0  # embed rides the odd class


def test_filtered_policy_saves_important_every_event(setup):
    model, _, _ = setup
    pol = _mk_policy("filtered", model, first_k=1, last_k=1, rest_every=3)
    nblocks = len(pol.blocks)
    for ev in range(7):
        sel = pol.select(PolicyContext(ev, 0))
        assert pol.blocks[0] in sel and pol.blocks[-1] in sel
        if ev % 3:
            assert len([u for u in sel if u.startswith("block")]) == 2
    # over 2 rest cycles, all blocks get covered
    union = set()
    for ev in range(7):
        union |= set(pol.select(PolicyContext(ev, 0)))
    assert union == set(pol.all_units())


def test_interval_policy_stripes(setup):
    model, _, _ = setup
    pol = _mk_policy("interval", model, stride=4)
    union = set()
    for ev in range(4):
        union |= {u for u in pol.select(PolicyContext(ev, 0))
                  if u.startswith("block")}
    assert union == set(pol.blocks)


def test_topk_delta_uses_scores(setup):
    model, _, _ = setup
    pol = _mk_policy("topk_delta", model, frac=0.5)
    scores = {b: float(i) for i, b in enumerate(pol.blocks)}
    sel = pol.select(PolicyContext(3, 0, drift_scores=scores))
    chosen = [u for u in sel if u.startswith("block")]
    assert chosen == sorted(pol.blocks, key=lambda b: -scores[b])[:2]


# --------------------------------------------------------------------- delta
def test_delta_tracker_detects_drift(setup):
    model, state, registry = setup
    tracker = DeltaTracker(registry)
    tracker.reset(state["params"])
    scores0 = tracker.scores(state["params"])
    assert all(v == 0 for v in scores0.values())
    # perturb one block only
    changed = registry.insert_unit(
        state["params"], "block_002",
        jax.tree.map(lambda x: np.asarray(x) * 1.5,
                     registry.extract_unit(state["params"], "block_002")))
    scores = tracker.scores(changed)
    top = max(scores, key=scores.get)
    assert top == "block_002"
    assert scores["block_000"] < 1e-6


# ------------------------------------------------------------------- yamlish
def test_yamlish_roundtrip_recipe():
    text = """
# a recipe
base: /ckpt/a@1000
output: /out/dir
optimizer: true
select:
  - units: block_000..block_003
    from: /ckpt/b@900
  - units: [embed, lm_head]
    from: /ckpt/b@900
"""
    d = yamlish.loads(text)
    assert d["base"] == "/ckpt/a@1000"
    assert d["optimizer"] is True
    assert d["select"][0]["units"] == "block_000..block_003"
    assert d["select"][1]["units"] == ["embed", "lm_head"]
    out = yamlish.dumps(d)
    d2 = yamlish.loads(out)
    assert d2 == d


def test_yamlish_scalars():
    d = yamlish.loads("a: 3\nb: 3.5\nc: null\nd: 'x y'\ne: false")
    assert d == {"a": 3, "b": 3.5, "c": None, "d": "x y", "e": False}


# ------------------------------------------------------------ explicit merge
def test_recipe_range_expansion(setup):
    model, _, registry = setup
    rule = SelectRule(units=["block_000..block_002", "embed"],
                      source=CheckpointRef("/x", 1))
    names = rule.expand(registry.unit_names())
    assert names == ["block_000", "block_001", "block_002", "embed"]
    with pytest.raises(KeyError):
        SelectRule(units=["nope"], source=CheckpointRef("/x", 1)).expand(
            registry.unit_names())


def test_explicit_merge_and_resume_equivalence(tmp_path, setup):
    """Frankenstein via recipe == manual unit mixing (weights AND opt)."""
    model, state, registry = setup
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path / "ck", registry, pol, async_save=False)
    mgr.save(state, step=100)
    state2 = jax.tree.map(lambda x: x * 1.5 if x.dtype != jnp.int32 else x,
                          state)
    mgr.save(state2, step=200)

    recipe = Recipe(
        base=CheckpointRef(tmp_path / "ck", 200),
        output=tmp_path / "merged",
        select=[SelectRule(units=["block_001", "embed"],
                           source=CheckpointRef(tmp_path / "ck", 100))])
    stats = merge(recipe, workers=2)
    assert stats["units"] == len(registry.unit_names())

    mgr2 = CheckpointManager(tmp_path / "merged", registry, pol,
                             async_save=False)
    got = mgr2.restore(steps_lib.state_specs(model))
    # block_001 + embed come from state (step 100), rest from state2
    for unit, src in [("block_001", state), ("embed", state),
                      ("block_000", state2), ("final_norm", state2)]:
        exp_w = registry.extract_unit(src["params"], unit)
        got_w = registry.extract_unit(got["params"], unit)
        for a, b in zip(jax.tree.leaves(exp_w), jax.tree.leaves(got_w)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        exp_o = registry.extract_opt_unit(src["opt"], unit)
        got_o = registry.extract_opt_unit(got["opt"], unit)
        for a, b in zip(jax.tree.leaves(exp_o), jax.tree.leaves(got_o)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()
    mgr2.close()


def test_merge_weights_only_mode(tmp_path, setup):
    model, state, registry = setup
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path / "ck", registry, pol, async_save=False)
    mgr.save(state, step=10)
    recipe = Recipe(base=CheckpointRef(tmp_path / "ck", 10),
                    output=tmp_path / "wonly", select=[], optimizer=False)
    merge(recipe, workers=1)
    from repro.core import ManifestStore
    out_m = ManifestStore(tmp_path / "wonly").load(10)
    assert out_m is not None
    assert all(set(kinds) == {"weights"} for kinds in out_m.entries.values())
    # only the weight objects were copied into the output store
    src_m = ManifestStore(tmp_path / "ck").load(10)
    weight_digests = {r["weights"].digest for r in src_m.entries.values()}
    files = list((tmp_path / "wonly" / "objects").glob("*/*.chunk"))
    assert files and {f.stem for f in files} == weight_digests
    mgr.close()
