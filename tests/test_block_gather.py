"""Fused gather kernel (fingerprint-compare + dirty-block compaction):
kernel-vs-oracle property sweeps, jnp-fallback bit-identity, the
capacity-overflow contract, and the int8 composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases

from repro.kernels.block_fp.ref import fingerprint_bytes
from repro.kernels.block_gather import (
    gather_dirty,
    gather_dirty_oracle,
    gather_tree_dirty,
    quantize_oracle,
    round_capacity,
)

BB = 1024  # small blocks so modest arrays span many of them


def _drift(a: np.ndarray, flat_positions):
    """Bump a handful of elements; returns the drifted copy."""
    b = a.copy()
    fl = b.reshape(-1)
    for p in flat_positions:
        q = fl[p % fl.size]
        fl[p % fl.size] = (q + 1).astype(b.dtype) if b.dtype != np.bool_ \
            else ~q
    return b


def _check(cur, base, *, capacity, bb=BB, interpret=None, quant=False):
    """Device result (pallas-interpret or jnp fallback) must be
    bit-identical to the numpy oracle on all authoritative outputs."""
    ref_fp = fingerprint_bytes(np.ascontiguousarray(base).tobytes(), bb)
    res = gather_dirty(jnp.asarray(cur), ref_fp, capacity=capacity,
                       block_bytes=bb, interpret=interpret,
                       quantize_int8=quant)
    fp, idx, out, count = gather_dirty_oracle(
        cur, ref_fp, capacity=res.capacity, block_bytes=bb)
    assert np.array_equal(np.asarray(res.fp), fp)
    assert np.array_equal(np.asarray(res.idx), idx)
    assert int(res.count) == count
    assert np.array_equal(
        np.asarray(res.blocks).view(np.uint8), out.view(np.uint8))
    if quant:
        q, scales = quantize_oracle(out)
        assert np.array_equal(np.asarray(res.q), q)
        assert np.array_equal(np.asarray(res.scales), scales)
    return res, count


# ------------------------------------------------------------ kernel vs ref
@pytest.mark.parametrize("dtype,shape", [
    (np.float32, (5000,)),
    (np.float16, (300, 7)),            # non-block-multiple, 2-byte dtype
    (np.float32, (4, 33, 9)),          # ragged 3D
    (np.int32, (64, 64)),
    (np.int8, (123,)),                 # 1-byte dtype
    (np.int16, (700,)),                # 2-byte integer
])
def test_kernel_matches_oracle(dtype, shape):
    rs = np.random.RandomState(sum(shape))
    base = (rs.standard_normal(shape) * 100).astype(dtype)
    cur = _drift(base, [0, 7, base.size // 2, base.size - 1])
    for interpret in (True, None):   # pallas-interpret and the jnp path
        _check(cur, base, capacity=8, interpret=interpret)


def test_bfloat16_and_bool():
    base = jnp.asarray(np.random.RandomState(0).standard_normal(3000),
                       jnp.bfloat16)
    cur = base.at[17].add(1).at[2500].add(1)
    ref_fp = fingerprint_bytes(np.asarray(base).tobytes(), BB)
    res = gather_dirty(cur, ref_fp, capacity=4, block_bytes=BB,
                       interpret=True)
    fp, idx, out, count = gather_dirty_oracle(
        np.asarray(cur), ref_fp, capacity=res.capacity, block_bytes=BB)
    assert np.array_equal(np.asarray(res.fp), fp)
    assert np.array_equal(np.asarray(res.idx), idx)
    assert int(res.count) == count == 2
    bools = np.zeros(4000, np.bool_)
    cur_b = _drift(bools, [5, 2100])
    _check(cur_b, bools, capacity=2)


def test_clean_input_gathers_nothing():
    a = np.arange(9000, dtype=np.float32)
    res, count = _check(a, a, capacity=4, interpret=True)
    assert count == 0
    assert np.all(np.asarray(res.idx) == -1)
    assert not np.asarray(res.blocks).any()


def test_capacity_overflow_is_detectable_and_prefix_valid():
    """The misprediction contract: count is authoritative past capacity,
    the first `capacity` dirty blocks are still exact and ascending."""
    rs = np.random.RandomState(3)
    base = rs.standard_normal(64 * (BB // 4)).astype(np.float32)
    cur = _drift(base, [i * (BB // 4) for i in range(0, 64, 2)])  # 32 dirty
    for interpret in (True, None):
        res, count = _check(cur, base, capacity=8, interpret=interpret)
        assert count == 32 > res.capacity == 8
        idx = np.asarray(res.idx)
        assert np.array_equal(idx, np.arange(0, 16, 2))  # ascending prefix


def test_no_reference_means_all_dirty():
    a = np.random.RandomState(1).standard_normal(4096).astype(np.float32)
    nb = -(-a.nbytes // BB)
    fp, idx, out, count = gather_dirty_oracle(a, None, capacity=nb,
                                              block_bytes=BB)
    assert count == nb and np.array_equal(idx, np.arange(nb))
    # mismatched table shape (meta change) is the same as no reference
    fp2, idx2, _, count2 = gather_dirty_oracle(
        a, np.zeros((nb + 3, 2), np.uint32), capacity=nb, block_bytes=BB)
    assert count2 == nb and np.array_equal(idx2, idx)


def test_property_sweep():
    def gen(rs):
        dtype = rs.choice(["float32", "float16", "int32"])
        n = int(rs.randint(1, 12000))
        nd = int(rs.randint(0, 10))
        cap = int(rs.randint(1, 16))
        bb = int(rs.choice([256, 1024]))
        seed = int(rs.randint(0, 2 ** 31))
        return dtype, n, nd, cap, bb, seed

    for dtype, n, nd, cap, bb, seed in cases(12, gen):
        rs = np.random.RandomState(seed)
        base = (rs.standard_normal(n) * 50).astype(dtype)
        cur = _drift(base, list(rs.randint(0, n, size=nd)))
        for interpret in (True, None):
            _check(cur, base, capacity=cap, bb=bb, interpret=interpret)


def test_quantize_composition_matches_oracle():
    rs = np.random.RandomState(7)
    base = rs.standard_normal(8 * (BB // 4)).astype(np.float32)
    cur = _drift(base, [3, BB // 4 * 5 + 1])
    for interpret in (True, None):
        _check(cur, base, capacity=2, interpret=interpret, quant=True)


def test_tree_gather_one_dispatch_per_unit():
    rs = np.random.RandomState(11)
    bases = [rs.standard_normal(3000).astype(np.float32),
             rs.standard_normal((70, 40)).astype(np.float32)]
    curs = [_drift(bases[0], [5]), _drift(bases[1], [100, 2000])]
    refs = [fingerprint_bytes(b.tobytes(), BB) for b in bases]
    results = gather_tree_dirty([jnp.asarray(c) for c in curs], refs,
                                [4, 4], block_bytes=BB, interpret=True)
    for cur, ref, res in zip(curs, refs, results):
        fp, idx, out, count = gather_dirty_oracle(
            cur, ref, capacity=res.capacity, block_bytes=BB)
        assert np.array_equal(np.asarray(res.fp), fp)
        assert np.array_equal(np.asarray(res.idx), idx)
        assert int(res.count) == count
        assert np.array_equal(
            np.asarray(res.blocks).view(np.uint8), out.view(np.uint8))


def test_round_capacity():
    assert round_capacity(0, 64) == 1
    assert round_capacity(1, 64) == 1
    assert round_capacity(3, 64) == 4
    assert round_capacity(33, 64) == 64
    assert round_capacity(500, 64) == 64
    assert round_capacity(5, 6) == 6       # pow2 clamp to n_blocks
    # the set of reachable capacities per leaf is O(log n_blocks)
    caps = {round_capacity(n, 4096) for n in range(1, 4097)}
    assert len(caps) <= 13
