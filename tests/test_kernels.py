"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.fused_adamw import adamw_ref, fused_adamw
from repro.kernels.quantize import dequantize, quantize, quantize_ref
from repro.kernels.ssd_scan import ssd_ref, ssd_scan


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("b,s,h,g,d,blk,dtype", [
    (1, 128, 4, 4, 64, 64, jnp.bfloat16),
    (2, 128, 4, 2, 64, 32, jnp.bfloat16),
    (1, 256, 8, 1, 128, 128, jnp.bfloat16),
    (2, 64, 2, 2, 32, 64, jnp.float32),
])
def test_flash_attention_causal(b, s, h, g, d, blk, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(k1, (b, s, h, d), dtype)
    k = jax.random.normal(k2, (b, s, g, d), dtype)
    v = jax.random.normal(k3, (b, s, g, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=blk, block_k=blk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), atol=tol, rtol=tol)


def test_flash_attention_non_causal_cross_len():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 64, 4, 64), jnp.bfloat16)
    k = jax.random.normal(k2, (2, 192, 2, 64), jnp.bfloat16)
    v = jax.random.normal(k3, (2, 192, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), atol=2e-2, rtol=2e-2)


def test_flash_attention_property_sweep():
    def gen(rs):
        d = int(rs.choice([32, 64]))
        g = int(rs.choice([1, 2, 4]))
        rep = int(rs.choice([1, 2]))
        s = int(rs.choice([64, 128]))
        return (int(rs.randint(1, 3)), s, g * rep, g, d)

    for b, s, h, g, d in cases(5, gen):
        ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, g, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, g, d), jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32), atol=3e-2,
                                   rtol=3e-2)


# ---------------------------------------------------------------------- ssd
@pytest.mark.parametrize("b,s,h,p,n,q", [
    (2, 64, 4, 16, 16, 16),
    (1, 128, 2, 32, 64, 32),
    (1, 96, 3, 8, 8, 32),
])
def test_ssd_scan_matches_recurrence(b, s, h, p, n, q):
    ks = jax.random.split(jax.random.PRNGKey(s + p), 4)
    xs = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    bs = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    cs = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    y, fin = ssd_scan(xs, dt, a_log, bs, cs, chunk=q, interpret=True)
    yr, fr = ssd_ref(xs, dt, a_log, bs, cs)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(fin, fr, atol=1e-4, rtol=1e-4)


def test_model_ssd_chunked_matches_ref():
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    b, s, h, p, n = 2, 80, 2, 16, 24  # deliberately non-chunk-multiple (80)
    xs = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 3.0, h))
    bs = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    cs = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    y, fin = ssd_chunked(xs, dt, a_log, bs, cs, 32)
    yr, fr = ssd_ref(xs, dt, a_log, bs, cs)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(fin, fr, atol=2e-4, rtol=2e-4)


# ----------------------------------------------------------------- quantize
def test_quantize_matches_numpy_codec():
    def gen(rs):
        return rs.standard_normal(int(rs.randint(10, 4000))).astype(np.float32)

    for arr in cases(6, gen):
        q, s = quantize(jnp.asarray(arr), interpret=True)
        qr, sr = quantize_ref(arr)
        assert np.array_equal(np.asarray(q).reshape(-1), qr)
        np.testing.assert_allclose(np.asarray(s).reshape(-1), sr, rtol=1e-6)
        x2 = dequantize(q, s, shape=arr.shape, interpret=True)
        amax = np.abs(arr).max() if arr.size else 1.0
        assert float(np.max(np.abs(np.asarray(x2) - arr))) <= amax / 127 + 1e-6


# -------------------------------------------------------------- fused adamw
@pytest.mark.parametrize("shape,step,wd", [((64, 33), 0, 0.0),
                                           ((257,), 5, 0.1),
                                           ((3, 5, 7), 100, 0.01)])
def test_fused_adamw_matches_ref(shape, step, wd):
    ks = jax.random.split(jax.random.PRNGKey(step + 1), 4)
    g = jax.random.normal(ks[0], shape, jnp.bfloat16)
    ma = jax.random.normal(ks[1], shape, jnp.float32)
    m = jax.random.normal(ks[2], shape, jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32)) * 0.01
    kw = dict(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=wd, step=step)
    p1, ma1, m1, v1 = fused_adamw(g, ma, m, v, interpret=True, **kw)
    p2, ma2, m2, v2 = adamw_ref(g, ma, m, v, **kw)
    np.testing.assert_allclose(ma1, ma2, atol=1e-6, rtol=1e-5)
    np.testing.assert_allclose(m1, m2, atol=1e-6, rtol=1e-5)
    np.testing.assert_allclose(v1, v2, atol=1e-7, rtol=1e-5)
    assert p1.dtype == jnp.bfloat16
