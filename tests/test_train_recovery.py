"""Integration: trainer + selective checkpointing + failure recovery
(paper Tables 1/4 semantics at smoke scale).

Each end-to-end trainer run takes tens of seconds, so the whole module is
marked ``slow`` (excluded from ``scripts/check.sh smoke``; still part of
the tier-1 gate)."""
import shutil

import numpy as np
import pytest

from repro.launch.train import SimulatedFailure, train

pytestmark = pytest.mark.slow

BASE = dict(arch="llama3.2-3b", total_steps=48, batch=4, seq_len=32,
            ckpt_interval=16, seed=11, lr=3e-3)


def test_loss_decreases(tmp_path):
    r = train(ckpt_dir=str(tmp_path / "a"), policy_name="full", **BASE)
    first = r["losses"][0][1]
    assert r["final_loss"] < first - 0.3


def test_full_policy_resume_bitwise_exact(tmp_path):
    r_ref = train(ckpt_dir=str(tmp_path / "ref"), policy_name="full", **BASE)
    with pytest.raises(SimulatedFailure):
        train(ckpt_dir=str(tmp_path / "f"), policy_name="full", fail_at=40,
              **BASE)
    r_res = train(ckpt_dir=str(tmp_path / "f"), policy_name="full",
                  resume=True, **BASE)
    # resumed tail losses must match the uninterrupted run exactly
    ref_tail = dict(r_ref["losses"])
    for step, loss in r_res["losses"]:
        assert loss == ref_tail[step], (step, loss, ref_tail[step])


@pytest.mark.parametrize("policy", ["parity", "filtered", "interval"])
def test_selective_resume_recovers(tmp_path, policy):
    r_ref = train(ckpt_dir=str(tmp_path / "ref"), policy_name="full", **BASE)
    with pytest.raises(SimulatedFailure):
        train(ckpt_dir=str(tmp_path / policy), policy_name=policy,
              fail_at=40, **BASE)
    r_res = train(ckpt_dir=str(tmp_path / policy), policy_name=policy,
                  resume=True, **BASE)
    # Frankenstein resume: final loss within a modest band of uninterrupted
    assert abs(r_res["final_loss"] - r_ref["final_loss"]) < 0.35, \
        (policy, r_res["final_loss"], r_ref["final_loss"])


def test_selective_saves_fewer_bytes(tmp_path):
    r_full = train(ckpt_dir=str(tmp_path / "full"), policy_name="full",
                   **BASE)
    r_par = train(ckpt_dir=str(tmp_path / "par"), policy_name="parity",
                  **BASE)
    # 3 events: full saves 3x everything; parity saves 1 full + 2 halves
    assert r_par["ckpt_bytes"] < 0.85 * r_full["ckpt_bytes"]


def test_topk_delta_policy_runs(tmp_path):
    r = train(ckpt_dir=str(tmp_path / "d"), policy_name="topk_delta", **BASE)
    assert np.isfinite(r["final_loss"])


def test_data_determinism_across_resume(tmp_path):
    """The same global step sees the same batch after restore."""
    from repro.data.synthetic import SyntheticTokens
    d1 = SyntheticTokens(vocab_size=100, batch=2, seq_len=16, seed=5)
    ref = [next(d1)["tokens"] for _ in range(6)]
    d2 = SyntheticTokens(vocab_size=100, batch=2, seq_len=16, seed=5)
    for _ in range(3):
        next(d2)
    state = d2.state_dict()
    d3 = SyntheticTokens(vocab_size=100, batch=2, seq_len=16, seed=5)
    d3.load_state(state)
    for i in range(3, 6):
        np.testing.assert_array_equal(next(d3)["tokens"], ref[i])
