"""Optimizer unit tests: AdamW math vs a numpy reference, clipping,
schedules, and the 7x checkpoint-byte anatomy."""
import jax
import jax.numpy as jnp
import numpy as np

from proptest import cases, rand_shape

from repro.configs import get_config
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.optim import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    warmup_cosine,
)


def _np_adamw(g, ma, m, v, lr, cfg, t, decay):
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m2 / (1 - cfg.b1 ** (t + 1))
    vh = v2 / (1 - cfg.b2 ** (t + 1))
    wd = cfg.weight_decay if decay else 0.0
    ma2 = ma - lr * (mh / (np.sqrt(vh) + cfg.eps) + wd * ma)
    return ma2, m2, v2


def test_adamw_matches_numpy():
    cfg = AdamWConfig()

    def gen(rs):
        shape = rand_shape(rs)
        return (rs.standard_normal(shape).astype(np.float32),
                rs.standard_normal(shape).astype(np.float32),
                int(rs.randint(0, 50)), bool(rs.randint(2)))

    for g_np, ma_np, t, decay in cases(6, gen):
        grads = {"w": jnp.asarray(g_np)}
        opt = {"master": {"w": jnp.asarray(ma_np)},
               "m": {"w": jnp.zeros_like(grads["w"])},
               "v": {"w": jnp.zeros_like(grads["w"])}}
        mask = {"w": decay}
        p, new_opt = adamw_update(grads, opt, lr=jnp.float32(1e-3),
                                  step=jnp.int32(t), cfg=cfg,
                                  decay_mask=mask)
        ma2, m2, v2 = _np_adamw(g_np, ma_np, np.zeros_like(g_np),
                                np.zeros_like(g_np), 1e-3, cfg, t, decay)
        np.testing.assert_allclose(new_opt["master"]["w"], ma2, rtol=2e-6,
                                   atol=2e-6)
        np.testing.assert_allclose(new_opt["m"]["w"], m2, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(new_opt["v"]["w"], v2, rtol=1e-6, atol=1e-8)
        assert p["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(250.0)) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # below threshold -> unchanged
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(clipped2["a"], g["a"])


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert all(lrs[i] >= lrs[i + 1] - 1e-9 for i in range(1, len(lrs) - 1))
    assert lrs[-1] >= 0.1 - 1e-6             # final_frac floor


def test_checkpoint_anatomy_is_7x_model_bytes():
    """Paper §2.2: full training state ~= 7x the bf16 model file."""
    model = build_model(get_config("llama3.2-3b", reduced=True))
    state = steps_lib.init_state(model, jax.random.key(0))
    p_bytes = sum(np.asarray(x).nbytes
                  for x in jax.tree.leaves(state["params"]))
    o_bytes = sum(np.asarray(x).nbytes
                  for x in jax.tree.leaves(state["opt"]))
    ratio = (p_bytes + o_bytes) / p_bytes
    assert abs(ratio - 7.0) < 0.01, ratio


def test_opt_state_fp32_master_matches_params():
    model = build_model(get_config("mamba2-370m", reduced=True))
    master = model.init(jax.random.key(1))
    opt = init_opt_state(master)
    for a, b in zip(jax.tree.leaves(master), jax.tree.leaves(opt["master"])):
        assert b.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b))
