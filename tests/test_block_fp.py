"""Block fingerprint pipeline: kernel-vs-oracle property sweeps, the
block-sparse delta v2 format, zero-D2H unchanged re-saves, restart
recovery, and the AsyncWriter wait()/close semantics."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import cases

from repro.checkpoint import AsyncWriteError, AsyncWriter
from repro.checkpoint import compression
from repro.checkpoint import fingerprint as fputil
from repro.checkpoint.saver import CheckpointManager
from repro.configs import get_config
from repro.core import DeltaTracker, LayerRegistry, make_policy
from repro.kernels.block_fp import (
    block_fingerprint,
    dirty_block_indices,
    fingerprint_array,
    fingerprint_tree,
    gather_blocks,
    leaves_match,
    tree_to_host,
)
from repro.launch import steps as steps_lib
from repro.models import build_model

BB = 4096  # small blocks so reduced-model leaves span many of them


# ------------------------------------------------------------ kernel vs ref
@pytest.mark.parametrize("dtype,shape", [
    (jnp.float32, (1000,)),
    (jnp.bfloat16, (300, 7)),          # non-block-multiple, 2-byte dtype
    (jnp.float32, (4, 33, 9)),         # stacked-unit-like 3D, ragged
    (jnp.int32, (64, 64)),
    (jnp.float16, (123,)),
    (jnp.bfloat16, (8, 2048)),         # exact block multiple
])
def test_kernel_matches_oracle(dtype, shape):
    x = jax.random.normal(jax.random.PRNGKey(sum(shape)), shape)
    x = (x * 100).astype(dtype)
    for bb in (1024, 65536):
        fp, ss = block_fingerprint(x, block_bytes=bb, interpret=True)
        ref = fingerprint_array(np.asarray(x), bb)
        assert np.array_equal(np.asarray(fp), ref.fp)
        np.testing.assert_allclose(np.asarray(ss), ref.sumsq, rtol=1e-4)


def test_kernel_property_sweep():
    def gen(rs):
        dtype = rs.choice(["float32", "bfloat16"])
        ndim = int(rs.randint(1, 4))
        shape = tuple(int(rs.randint(1, 40)) for _ in range(ndim))
        return dtype, shape, int(rs.choice([256, 1024]))

    for dtype, shape, bb in cases(10, gen):
        a = np.random.RandomState(len(shape)).standard_normal(shape)
        x = jnp.asarray(a, dtype=dtype)
        fp, _ = block_fingerprint(x, block_bytes=bb, interpret=True)
        ref = fingerprint_array(np.asarray(x), bb)
        assert np.array_equal(np.asarray(fp), ref.fp), (dtype, shape, bb)


def test_fingerprint_localizes_dirty_blocks():
    rs = np.random.RandomState(0)
    a = rs.standard_normal(8 * 1024).astype(np.float32)  # 32 KiB, 8 blocks
    b = a.copy()
    b[5 * 1024 + 3] += 1.0  # dirty exactly block 5
    ca = fingerprint_array(a, BB)
    cb = fingerprint_array(b, BB)
    assert list(dirty_block_indices(cb, ca)) == [5]
    # gather moves exactly that block, with the changed value in place
    g = np.asarray(gather_blocks(jnp.asarray(b), np.array([5]),
                                 block_bytes=BB))
    assert g.shape == (1, BB // 4)
    np.testing.assert_array_equal(g[0], b[5 * 1024:6 * 1024])


def test_tree_fingerprint_roundtrip_and_match():
    tree = {"w": jnp.arange(3000, dtype=jnp.float32),
            "b": {"c": jnp.ones((17, 5), jnp.bfloat16)}}
    cur = fingerprint_tree(tree, block_bytes=BB, interpret=True)
    assert leaves_match(cur, cur)
    # a host table packed/unpacked through the envelope format still matches
    table = fputil.pack_table(tree_to_host(cur))
    assert leaves_match(cur, fputil.unpack_table(table))
    # digest is content-derived and sensitive to any leaf change
    tree2 = {"w": tree["w"].at[0].add(1), "b": tree["b"]}
    cur2 = fingerprint_tree(tree2, block_bytes=BB, interpret=True)
    assert not leaves_match(cur2, cur)
    t2 = fputil.pack_table(tree_to_host(cur2))
    assert fputil.fp_digest(t2) != fputil.fp_digest(table)


# ------------------------------------------------------- block delta format
def test_block_delta_codec_roundtrip():
    rec = {"name": "w", "shape": [100], "dtype": "float32", "nbytes": 400,
           "block": 64, "idx": [1, 3], "data": bytes(range(64)) * 2}
    blob = compression.block_delta_encode([rec], compress="none")
    assert compression.is_block_delta(blob)
    out = compression.block_delta_decode(blob)
    assert out[0]["idx"] == [1, 3] and out[0]["data"] == rec["data"]


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("llama3.2-3b", reduced=True)
    model = build_model(cfg)
    state = steps_lib.init_state(model, jax.random.key(0))
    registry = LayerRegistry(model)
    return model, state, registry


def _drift_unit(registry, state, unit, n=10):
    sub = registry.extract_unit(state["params"], unit)
    leaves, treedef = jax.tree.flatten(sub)
    a = np.asarray(leaves[0]).copy()
    a.flat[:n] += 1
    leaves[0] = jnp.asarray(a)
    return dict(state, params=registry.insert_unit(
        state["params"], unit, jax.tree.unflatten(treedef, leaves)))


def test_block_sparse_delta_restores_bitwise(tmp_path, small_setup):
    model, state, registry = small_setup
    mgr = CheckpointManager(tmp_path, registry,
                            make_policy("full", model.layer_units()),
                            async_save=False, fp_block_bytes=BB)
    mgr.save(state, step=10)
    state2 = _drift_unit(registry, state, "block_001")
    mgr.save(state2, step=20)
    s = mgr.last_save_stats
    assert s["delta_chunks"] == 1          # only the drifted unit rewrote
    assert 0 < s["d2h_bytes"] < s["logical_bytes"] / 10
    restored = mgr.restore(steps_lib.state_specs(model))
    for key in ("params", "opt"):
        for a, b in zip(jax.tree.leaves(state2[key]),
                        jax.tree.leaves(restored[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


def test_unchanged_resave_zero_d2h(tmp_path, small_setup):
    """Acceptance: a re-save of unchanged content transfers ZERO payload
    bytes device->host and hashes zero payload bytes."""
    model, state, registry = small_setup
    mgr = CheckpointManager(tmp_path, registry,
                            make_policy("full", model.layer_units()),
                            async_save=True, fp_block_bytes=BB)
    mgr.save(state, step=10)
    assert mgr.last_save_stats["d2h_bytes"] > 0  # first event is full
    mgr.save(state, step=20)
    s = mgr.last_save_stats
    assert s["d2h_bytes"] == 0
    assert s["hashed_bytes"] == 0
    assert s["written_bytes"] == 0
    assert s["dirty_block_frac"] == 0.0
    assert s["dedup_hits"] == 2 * len(registry.units)
    # the dedup'd manifest still restores bitwise
    restored = mgr.restore(steps_lib.state_specs(model))
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


def test_restart_recovers_fingerprints(tmp_path, small_setup):
    """After a process restart the reference vectors reload from the object
    envelopes: an unchanged re-save is still zero-D2H."""
    model, state, registry = small_setup
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path, registry, pol, async_save=False,
                            fp_block_bytes=BB)
    mgr.save(state, step=10)
    mgr.close()
    mgr2 = CheckpointManager(tmp_path, registry, pol, async_save=False,
                             fp_block_bytes=BB)
    mgr2.save(state, step=20)
    assert mgr2.last_save_stats["d2h_bytes"] == 0
    mgr2.close()


def test_v1_xor_chunks_still_read(tmp_path, small_setup):
    """Legacy path compatibility: objects written without fingerprinting
    (canonical digests, XOR deltas) read back alongside v2 objects."""
    model, state, registry = small_setup
    pol = make_policy("full", model.layer_units())
    legacy = CheckpointManager(tmp_path, registry, pol, async_save=False,
                               fingerprint=False)
    legacy.save(state, step=10)
    state2 = _drift_unit(registry, state, "block_000")
    legacy.save(state2, step=20)
    assert legacy.last_save_stats["delta_chunks"] > 0  # wrote XOR deltas
    legacy.close()
    # a fingerprinting manager on the same root restores the v1 chain...
    mgr = CheckpointManager(tmp_path, registry, pol, async_save=False,
                            fp_block_bytes=BB)
    restored = mgr.restore(steps_lib.state_specs(model))
    for a, b in zip(jax.tree.leaves(state2["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ...and writes v2 objects on top of it without disturbing v1 reads
    state3 = _drift_unit(registry, state2, "block_001")
    mgr.save(state3, step=30)
    restored3 = mgr.restore(steps_lib.state_specs(model))
    for a, b in zip(jax.tree.leaves(state3["params"]),
                    jax.tree.leaves(restored3["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


def test_corrupt_block_delta_falls_back(tmp_path, small_setup):
    model, state, registry = small_setup
    mgr = CheckpointManager(tmp_path, registry,
                            make_policy("full", model.layer_units()),
                            async_save=False, fp_block_bytes=BB)
    mgr.save(state, step=10)
    state2 = _drift_unit(registry, state, "block_000")
    mgr.save(state2, step=20)
    m2 = mgr.manifests.load(20)
    victim = tmp_path / m2.entries["block_000"]["weights"].relpath
    raw = bytearray(victim.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    victim.write_bytes(bytes(raw))
    restored = mgr.restore(steps_lib.state_specs(model))
    # block_000 fell back to its step-10 content
    exp = registry.extract_unit(state["params"], "block_000")
    got = registry.extract_unit(restored["params"], "block_000")
    for a, b in zip(jax.tree.leaves(exp), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()


# ----------------------------------------------------------- delta tracker
def test_tracker_keeps_no_weight_copies(small_setup):
    model, state, registry = small_setup
    tracker = DeltaTracker(registry, block_bytes=BB)
    tracker.reset(state["params"])
    param_bytes = sum(np.asarray(x).nbytes
                      for x in jax.tree.leaves(state["params"]))
    fp_bytes = sum(
        np.asarray(l.fp).nbytes + np.asarray(l.sumsq).nbytes
        for leaves in tracker._refs.values() for l in leaves)
    assert fp_bytes < param_bytes / 100  # vectors, not reference weights
    scores = tracker.scores(state["params"])
    assert all(v == 0.0 for v in scores.values())


def test_tracker_ranks_magnitude(small_setup):
    model, state, registry = small_setup
    tracker = DeltaTracker(registry, block_bytes=BB)
    tracker.reset(state["params"])
    # big scale on block_002, small (but bf16-representable) nudge on
    # block_001
    params = registry.insert_unit(
        state["params"], "block_002",
        jax.tree.map(lambda x: np.asarray(x) * 1.5,
                     registry.extract_unit(state["params"], "block_002")))
    params = registry.insert_unit(
        params, "block_001",
        jax.tree.map(lambda x: np.asarray(x) * 1.01,
                     registry.extract_unit(params, "block_001")))
    scores = tracker.scores(params)
    assert max(scores, key=scores.get) == "block_002"
    assert scores["block_001"] > scores["block_000"] == 0.0
    assert scores["block_002"] == pytest.approx(0.5, rel=0.05)


# ------------------------------------------------------------ async writer
def test_pending_result_wait():
    w = AsyncWriter(num_threads=1)
    release = threading.Event()

    def slow():
        release.wait(5)
        return 42

    p = w.submit(slow)
    assert not p.done()
    release.set()
    assert p.wait(5)
    assert p.result() == 42
    w.wait()  # the documented alias of drain()
    w.close()


def test_submit_after_close_raises_and_never_hangs():
    w = AsyncWriter(num_threads=2)
    w.close()
    with pytest.raises(AsyncWriteError):
        w.submit(lambda: None)


def test_concurrent_close_and_submit_no_lost_work():
    """Race regression: a submit that wins the open-check must have its
    item processed (never stranded behind the shutdown sentinels)."""
    for _ in range(8):
        w = AsyncWriter(num_threads=2)
        results = []
        stop = threading.Event()

        def submitter():
            i = 0
            while not stop.is_set():
                try:
                    results.append(w.submit(lambda v=i: v))
                except AsyncWriteError:
                    return
                i += 1

        t = threading.Thread(target=submitter)
        t.start()
        time.sleep(0.002)
        stop.set()
        w.close()
        t.join(5)
        assert not t.is_alive()
        for p in results:  # every accepted submit resolved
            assert p.wait(5)
