"""Resiliency: the crash matrix (every named crash point x every save
path must leave the previous manifest authoritative and restore
bit-exact), the --fail-at N@point trainer CLI, and the supervisor
acceptance drill (kill + SIGTERM preemption -> elastic restart on fewer
participants -> bit-exact resume with no committed step lost)."""
import jax
import numpy as np
import pytest

from repro.checkpoint import faults
from repro.checkpoint.async_io import AsyncWriteError
from repro.checkpoint.faults import InjectedCrash
from repro.checkpoint.saver import CheckpointManager
from repro.checkpoint.sharded import ShardBarrierError, ShardedCheckpointer
from repro.configs import get_config
from repro.core import LayerRegistry, make_policy
from repro.launch import steps as steps_lib
from repro.models import build_model

ARCH = "mamba2-370m"


@pytest.fixture(autouse=True)
def _clean_faults():
    # A crash test that dies mid-assert must not leave an armed point
    # behind to detonate inside an unrelated test.
    faults.disarm()
    yield
    faults.disarm()


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH, reduced=True)
    model = build_model(cfg)
    state1 = steps_lib.init_state(model, jax.random.key(0))

    def poke(x):
        x = np.array(x)
        x.flat[:1] += 1
        return x

    # Every leaf of every unit drifts, so every (unit, kind) of the
    # second event really exercises gather/write (no dedup early-outs
    # that would skip an armed point).
    state2 = {"step": np.array(state1["step"]),
              "params": jax.tree.map(poke, state1["params"]),
              "opt": jax.tree.map(poke, state1["opt"])}
    return model, LayerRegistry(model), state1, state2


def _assert_states_equal(a, b, parts=("params", "opt")):
    for part in parts:
        for x, y in zip(jax.tree.leaves(a[part]), jax.tree.leaves(b[part])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------- the matrix
# Which named crash points are reachable on which save path.  "spill"
# needs the tiered backend (and is armed sticky: the tiered drain
# RETRIES failed spills, so a one-shot crash would heal mid-save and the
# commit would legitimately succeed).  The sharded path adds the
# two-phase-commit points.
MATRIX = (
    [("local", p) for p in ("fingerprint", "gather", "object_write",
                            "manifest_commit", "manifest_latest")]
    + [("tiered", p) for p in ("fingerprint", "gather", "object_write",
                               "spill", "manifest_commit",
                               "manifest_latest")]
    + [("sharded", p) for p in ("fingerprint", "gather", "object_write",
                                "participant_record", "barrier",
                                "manifest_commit", "manifest_latest")]
)


def _make_saver(path_kind, root, model, registry):
    pol = make_policy("full", model.layer_units())
    # 4 KiB fingerprint blocks: one-element pokes stay block-sparse.
    if path_kind == "tiered":
        # spill_barrier=True makes the commit DEPEND on the spill drain,
        # so an injected spill failure must abort the event.
        mgr = CheckpointManager(root, registry, pol, fp_block_bytes=4096,
                                store_backend="tiered", spill_barrier=True)
        return mgr, mgr
    mgr = CheckpointManager(root, registry, pol, fp_block_bytes=4096)
    if path_kind == "sharded":
        return mgr, ShardedCheckpointer(mgr, 2)
    return mgr, mgr


@pytest.mark.parametrize("path_kind,point", MATRIX,
                         ids=[f"{b}-{p}" for b, p in MATRIX])
def test_crash_matrix_previous_manifest_stays_authoritative(
        setup, tmp_path, path_kind, point):
    """Arm one crash point, die mid-save of event 2, and prove event 1
    is untouched: its manifest is still LATEST, restore is bit-exact,
    with zero fallbacks, and survives a GC."""
    model, registry, state1, state2 = setup
    mgr, saver = _make_saver(path_kind, tmp_path, model, registry)
    saver.save(state1, step=10)

    with faults.scoped(point, sticky=(point == "spill")):
        with pytest.raises((InjectedCrash, AsyncWriteError,
                            ShardBarrierError)):
            saver.save(state2, step=20)
    assert not faults.pending()  # scoped() left nothing armed behind
    try:
        # Best-effort shutdown of the wounded manager: lanes may still
        # hold the injected error, exactly like a dying process.
        mgr.close()
    except (AsyncWriteError, InjectedCrash):
        pass

    # "Restart": a fresh manager on the same root sees step 10 as the
    # committed truth, whatever debris step 20 left behind (half-written
    # objects, participant records, even a manifest file without a
    # LATEST pointer for the manifest_latest case).
    backend = "tiered" if path_kind == "tiered" else "local"
    pol = make_policy("full", model.layer_units())
    mgr2 = CheckpointManager(tmp_path, registry, pol, async_save=False,
                             store_backend=backend)
    assert mgr2.manifests.latest_step() == 10
    like = steps_lib.state_specs(model)
    got = mgr2.restore(like)
    assert int(np.asarray(got["step"])) == 10
    _assert_states_equal(state1, got)
    assert not mgr2.last_restore_stats["fallback_units"]
    # GC with the rebuilt refcounts must not touch the live manifest's
    # objects (step 20's orphans MAY be swept — they are unreferenced).
    mgr2.gc()
    got2 = mgr2.restore(like)
    _assert_states_equal(state1, got2)
    mgr2.close()


def test_crash_then_retry_same_step_commits(setup, tmp_path):
    """After a mid-save death the SAME step can be retried and commits
    cleanly — the restart path a supervisor actually takes."""
    model, registry, state1, state2 = setup
    mgr, saver = _make_saver("sharded", tmp_path, model, registry)
    saver.save(state1, step=10)
    with faults.scoped("participant_record"):
        with pytest.raises(InjectedCrash):
            saver.save(state2, step=20)
    manifest = saver.save(state2, step=20)  # retry, same step
    assert manifest.step == 20
    assert mgr.manifests.latest_step() == 20
    got = mgr.restore(steps_lib.state_specs(model))
    _assert_states_equal(state2, got)
    mgr.close()


def test_swap_apply_crash_keeps_serving_previous_weights(setup, tmp_path):
    """The reader-side entry of the crash catalog: ``swap_apply`` fires
    mid-promotion inside ``swap.WeightService.swap``.  The server must
    keep answering from the PREVIOUS weights (never a half-applied
    tensor) and the next poll must complete the identical swap."""
    from repro.checkpoint.swap import WeightService

    model, registry, state1, state2 = setup
    pol = make_policy("full", model.layer_units())
    mgr = CheckpointManager(tmp_path, registry, pol, async_save=False,
                            fp_block_bytes=4096)
    mgr.save(state1, step=10)
    mgr.save(state2, step=20)
    like = steps_lib.state_specs(model)
    svc = WeightService(mgr, like, step=10)
    cold10 = mgr.restore(like, parts=("params",), step=10)

    # Die on the SECOND changed unit: some units already staged — none
    # of them may be visible to readers.
    with faults.scoped("swap_apply", hit=2):
        with pytest.raises(InjectedCrash):
            svc.poll()
    assert not faults.pending()
    assert svc.step == 10
    _assert_states_equal({"params": svc.current()},
                         {"params": cold10["params"]}, parts=("params",))

    # Recovery: digest diffing makes the redo idempotent — one clean
    # poll lands the full promotion, bit-exact vs a cold restore of 20.
    stats = svc.poll()
    assert stats is not None and svc.step == 20
    cold20 = mgr.restore(like, parts=("params",), step=20)
    _assert_states_equal({"params": svc.current()},
                         {"params": cold20["params"]}, parts=("params",))
    mgr.close()


# ----------------------------------------------------------- trainer CLI
def test_fail_at_crash_point_reaches_mid_save_and_resumes(tmp_path):
    """--fail-at N@point dies INSIDE the save pipeline (here: between
    the manifest write and the LATEST flip — the torn commit), and a
    --resume run picks up from the last committed step."""
    from repro.launch.train import train

    kw = dict(arch=ARCH, total_steps=8, batch=2, seq_len=16,
              ckpt_interval=4, ckpt_dir=str(tmp_path), seed=3)
    with pytest.raises(InjectedCrash):
        train(fail_at="8@manifest_latest", **kw)
    faults.disarm()
    from repro.core.manifest import ManifestStore
    ms = ManifestStore(tmp_path)
    # the torn commit: manifest file exists, LATEST still points at 4
    assert ms.latest_step() == 4
    assert (tmp_path / "manifests" / "manifest-00000008.json").is_file()

    out = train(resume=True, **kw)
    assert out["steps"] == 4  # resumed from 4, not from 0 or 8
    assert ms.latest_step() == 8


def test_fail_at_unreached_point_fails_loudly(tmp_path):
    """An armed point the run never reaches must error, not silently
    pass the drill."""
    from repro.launch.train import SimulatedFailure, train

    with pytest.raises(SimulatedFailure, match="never reached"):
        # step 6 has no checkpoint event (interval 4, total 6 -> only
        # step 4 saves AFTER the arming at step 6... no event follows).
        train(arch=ARCH, total_steps=6, batch=2, seq_len=16,
              ckpt_interval=4, ckpt_dir=str(tmp_path), seed=3,
              fail_at="6@gather")
    assert not faults.pending()


# ------------------------------------------------------------- supervisor
@pytest.mark.slow
def test_supervisor_kill_and_preempt_bit_exact_acceptance(tmp_path):
    """The ISSUE acceptance drill: SIGKILL mid-run, then SIGTERM
    preemption (hot save, durability barrier waived), each restart on a
    possibly smaller participant count, and the merged loss trajectory
    is bit-exact against an uninterrupted reference run — no committed
    step lost, preemption loses nothing at all."""
    from repro.launch.elastic import probe_restore
    from repro.launch.supervisor import (
        Injection,
        Supervisor,
        merged_losses,
    )
    from repro.launch.train import train

    kw = dict(arch="llama3.2-3b", total_steps=18, batch=2, seq_len=16,
              ckpt_interval=6, seed=11)
    ref = train(ckpt_dir=str(tmp_path / "ref"), **kw)
    ref_losses = dict(ref["losses"])

    sup = Supervisor(
        tmp_path / "ckpt", run_dir=tmp_path / "run",
        arch="llama3.2-3b", steps=18, interval=6, batch=2, seq_len=16,
        policy="full", seed=11,
        participants=(2, 2, 1),  # shrink to 1 for the final attempt
        injections=[Injection("kill", at_step=7),
                    Injection("sigterm", at_step=13)],
        verify_restore=True)
    report = sup.run()

    assert report["completed"]
    kill, preempt = report["interruptions"]
    assert kill["kind"] == "kill" and not kill["preempted"]
    # a hard kill loses at most one checkpoint cadence of steps
    assert 0 <= kill["lost_steps"] <= 6
    assert kill["committed_step"] >= 6
    assert preempt["kind"] == "sigterm" and preempt["preempted"]
    # preemption-time hot save: NOTHING committed is lost
    assert preempt["lost_steps"] == 0
    assert preempt["committed_step"] == preempt["reached_step"]
    for inter in (kill, preempt):
        assert inter["mttr_seconds"] is not None
        assert not inter["restore_probe"]["fallback_units"]
    assert report["goodput_steps"] is not None
    assert 0 < report["goodput_steps"] <= 1.0

    # Bit-exact resume: every step the (surviving) attempt CSVs recorded
    # matches the uninterrupted reference exactly, through both the
    # crash restart and the preemption restart, across the 2->1
    # participant shrink.
    merged = merged_losses(tmp_path / "run")
    assert merged and max(merged) == 17  # the final attempt finished
    for s, loss in merged.items():
        assert loss == ref_losses[s], (s, loss, ref_losses[s])

    # And the finished checkpoint restores on a fresh single-host mesh.
    probe = probe_restore(tmp_path / "ckpt", "llama3.2-3b")
    assert probe["step"] == 18
    assert not probe["fallback_units"]
