"""Serve a model from an LLMTailor checkpoint with batched prefill+decode.

    PYTHONPATH=src python examples/serve_batched.py [arch]
"""
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import serve  # noqa: E402
from repro.launch.train import train  # noqa: E402


def main() -> None:
    arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-3b"
    ckpt = tempfile.mkdtemp(prefix="serve_demo_")
    print(f"== training {arch} briefly to produce a servable checkpoint ==")
    train(arch=arch, total_steps=40, batch=8, seq_len=64, policy_name="full",
          ckpt_interval=40, ckpt_dir=ckpt, lr=2e-3)
    print("== serving from the checkpoint ==")
    out = serve(arch=arch, batch=4, prompt_len=32, new_tokens=16,
                from_ckpt=ckpt)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
