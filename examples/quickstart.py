"""Quickstart: train a small LM with LLMTailor parity checkpointing, kill
it, and resume from the Frankenstein merge.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import SimulatedFailure, train  # noqa: E402


def main() -> None:
    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_")
    common = dict(arch="llama3.2-3b", total_steps=80, batch=8, seq_len=64,
                  policy_name="parity", ckpt_interval=20, ckpt_dir=ckpt_dir,
                  lr=2e-3)

    print("== phase 1: train with parity checkpoints, fail at step 65 ==")
    try:
        train(fail_at=65, **common)
    except SimulatedFailure as e:
        print(f"  !! {e}")

    print("== phase 2: resume from the implicit Frankenstein merge ==")
    result = train(resume=True, **common)
    print(f"  final loss      : {result['final_loss']:.4f}")
    print(f"  ckpt bytes      : {result['ckpt_bytes']/2**20:.1f} MiB")
    print(f"  ckpt time frac  : {result['ckpt_time_fraction']*100:.1f}%")
    print(f"  checkpoints in  : {ckpt_dir}")


if __name__ == "__main__":
    main()
