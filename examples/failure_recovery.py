"""Fault-tolerance tour: corruption fallback + elastic restart + dynamic
(topk_delta) checkpointing.

    PYTHONPATH=src python examples/failure_recovery.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import LayerRegistry, make_policy  # noqa: E402
from repro.checkpoint.saver import CheckpointManager  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.train import SimulatedFailure, train  # noqa: E402
from repro.models import build_model  # noqa: E402


def corruption_demo() -> None:
    print("== corruption fallback ==")
    cfg = get_config("llama3.2-3b", reduced=True)
    model = build_model(cfg)
    state = steps_lib.init_state(model, jax.random.key(0))
    reg = LayerRegistry(model)
    root = Path(tempfile.mkdtemp(prefix="corrupt_demo_"))
    mgr = CheckpointManager(root, reg,
                            make_policy("full", model.layer_units()),
                            async_save=False)
    mgr.save(state, step=10)
    # drift before the second save: identical states would dedup to the
    # SAME object, leaving no older chunk to fall back on
    state2 = jax.tree.map(
        lambda x: x * 1.5 if x.dtype != np.int32 else x, state)
    m2 = mgr.save(state2, step=20)
    victim = root / m2.entries["block_000"]["weights"].relpath
    raw = bytearray(victim.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    victim.write_bytes(bytes(raw))
    print("  corrupted", victim.name, "(block_000 weights at step 20)")
    restored = mgr.restore(steps_lib.state_specs(model))
    print(f"  restore survived; resumed step = {int(restored['step'])} "
          "(block_000 transparently fell back to step 10)")
    mgr.close()


def dynamic_policy_demo() -> None:
    print("== dynamic topk_delta checkpointing ==")
    d = tempfile.mkdtemp(prefix="delta_demo_")
    try:
        train(arch="llama3.2-3b", total_steps=60, batch=8, seq_len=64,
              policy_name="topk_delta", ckpt_interval=20, ckpt_dir=d,
              fail_at=55, lr=2e-3)
    except SimulatedFailure as e:
        print(f"  !! {e}")
    r = train(arch="llama3.2-3b", total_steps=60, batch=8, seq_len=64,
              policy_name="topk_delta", ckpt_interval=20, ckpt_dir=d,
              resume=True, lr=2e-3)
    print(f"  resumed; final loss {r['final_loss']:.4f}; "
          f"ckpt bytes {r['ckpt_bytes']/2**20:.1f} MiB")


def main() -> None:
    corruption_demo()
    dynamic_policy_demo()
    print("(elastic restart across device counts: "
          "see tests/test_mesh_subprocess.py::test_elastic_restore_onto_other_meshes)")


if __name__ == "__main__":
    main()
