"""LLMTailor explicit merge: write a YAML recipe mixing layers from two
checkpoints of a training run and assemble a resumable Frankenstein, then
keep training from it (the paper's T2 + T3 workflow).

    PYTHONPATH=src python examples/merge_recipe.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import Recipe, merge  # noqa: E402
from repro.launch.train import train  # noqa: E402


RECIPE_TMPL = """
# LLMTailor recipe: odd blocks + embed from step 40, the rest from step 80
base: {root}@80
output: {out}
optimizer: true
select:
  - units: [block_001, block_003, embed]
    from: {root}@40
"""


def main() -> None:
    root = Path(tempfile.mkdtemp(prefix="merge_demo_")) / "ckpt"
    out = root.parent / "franken"

    print("== phase 1: training run producing checkpoints @40 and @80 ==")
    train(arch="llama3.2-3b", total_steps=80, batch=8, seq_len=64,
          policy_name="full", ckpt_interval=40, ckpt_dir=str(root), lr=2e-3)

    print("== phase 2: YAML-recipe merge ==")
    recipe_path = root.parent / "recipe.yaml"
    recipe_path.write_text(RECIPE_TMPL.format(root=root, out=out))
    stats = merge(Recipe.load(recipe_path), workers=2)
    print(f"  merged {stats['units']} units / {stats['chunks']} chunks "
          f"({stats['bytes']/2**20:.1f} MiB) in {stats['seconds']:.2f}s")

    print("== phase 3: resume training FROM the Frankenstein ==")
    result = train(arch="llama3.2-3b", total_steps=120, batch=8, seq_len=64,
                   policy_name="full", ckpt_interval=40, ckpt_dir=str(out),
                   resume=True, lr=2e-3)
    print(f"  resumed from step 80 -> 120; final loss "
          f"{result['final_loss']:.4f}")


if __name__ == "__main__":
    main()
